//! Fixed-point arithmetic primitives, a faithful Rust port of the semantics
//! of gemmlowp's `fixedpoint` library that the paper relies on (§2.2, App. B).
//!
//! The paper's inference engine never touches floating point at run time.
//! Every real-valued multiplier `M ∈ (0,1)` is normalized offline to
//! `M = 2^-n · M0` with `M0 ∈ [0.5, 1)` stored as a Q0.31 int32, and applied
//! with two primitives:
//!
//! * [`srdhm`] — *saturating rounding doubling high multiply*, the exact
//!   semantics of the ARM NEON `SQRDMULH` instruction (App. B stresses the
//!   correctly-rounding `SQRDMULH`, not `SQDMULH`).
//! * [`rounding_div_by_pot`] — rounding right shift with round-to-nearest,
//!   *ties away from zero*. App. B explains why NEON's `RSHL` (ties upward)
//!   is wrong: it biases results upward and measurably hurts accuracy.
//!
//! On top of these, [`Fp`] provides a typed fixed-point value with a
//! compile-time number of integer bits, mirroring gemmlowp's
//! `FixedPoint<tIntegerBits>`, used by the transcendental functions
//! (App. A.1) in [`transcendental`].

pub mod transcendental;

pub use transcendental::{exp_on_negative_values, logistic, tanh};

/// Saturating rounding doubling high multiply: `(a * b * 2 + 2^30) >> 31`
/// with saturation on the single overflow case `a == b == i32::MIN`.
///
/// This is the exact arithmetic of ARM NEON `SQRDMULH` (App. B) and is the
/// workhorse of fixed-point multiplication: for Q0.31 operands it computes
/// the Q0.31 product rounded to nearest.
#[inline]
pub fn srdhm(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = i64::from(a) * i64::from(b);
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1 << 30) };
    // (ab + nudge) / 2^31 with truncation toward zero, as in gemmlowp.
    ((ab + nudge) / (1i64 << 31)) as i32
}

/// Rounding right shift by `exponent` with round-to-nearest and ties
/// rounded *away from zero*.
///
/// App. B: NEON's `RSHL` rounds ties upward (e.g. `-12 >> 3` gives `-1`
/// instead of `-2`), creating an upward bias that degrades end-to-end
/// accuracy; this function implements the fix-up semantics gemmlowp uses.
#[inline]
pub fn rounding_div_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!(exponent >= 0, "rounding_div_by_pot is a right shift");
    if exponent == 0 {
        return x;
    }
    if exponent > 31 {
        // `x >> e` with e ≥ 32 is an overflowing shift: debug builds panic
        // and release builds wrap the shift amount mod 32, silently
        // producing garbage. Saturate to the mathematically exact result
        // instead: |x / 2^e| ≤ 2^31 / 2^32 = 0.5, with equality reached
        // only by x = i32::MIN at e = 32 — a tie, rounded away from zero
        // to −1; every other (x, e) rounds to 0.
        return if exponent == 32 && x == i32::MIN { -1 } else { 0 };
    }
    let mask: i32 = (1i64 << exponent).wrapping_sub(1) as i32;
    let remainder = x & mask;
    let threshold = (mask >> 1) + i32::from(x < 0);
    (x >> exponent) + i32::from(remainder > threshold)
}

/// Saturating multiplication by a power of two `2^exponent`.
///
/// Negative exponents are rounding right shifts; positive exponents are
/// left shifts that saturate instead of wrapping (gemmlowp
/// `SaturatingRoundingMultiplyByPOT`).
#[inline]
pub fn saturating_rounding_mul_by_pot(x: i32, exponent: i32) -> i32 {
    if exponent <= 0 {
        rounding_div_by_pot(x, -exponent)
    } else if exponent >= 32 {
        // The min/max probes below would themselves be overflowing shifts
        // (wrapped mod 32 in release); 2^exponent saturates every nonzero x.
        if x > 0 {
            i32::MAX
        } else if x < 0 {
            i32::MIN
        } else {
            0
        }
    } else {
        let min = i32::MIN >> exponent;
        let max = i32::MAX >> exponent;
        if x > max {
            i32::MAX
        } else if x < min {
            i32::MIN
        } else {
            x << exponent
        }
    }
}

/// Apply a normalized quantized multiplier `M = M0 · 2^-shift` (eq. 6) to an
/// int32 accumulator: `srdhm` by the Q0.31 mantissa `m0`, then rounding
/// right shift.
///
/// This is the scale-down step of the fused layer (§2.4): it maps the int32
/// accumulator (scale `S1·S2`) onto the output activation scale `S3`.
#[inline]
pub fn multiply_by_quantized_multiplier(acc: i32, m0: i32, right_shift: i32) -> i32 {
    debug_assert!(m0 >= 0, "normalized multiplier mantissa is non-negative");
    rounding_div_by_pot(srdhm(acc, m0), right_shift)
}

/// As [`multiply_by_quantized_multiplier`] but supporting multipliers ≥ 1
/// (`shift > 0` applies a saturating left shift before the fixed-point
/// multiply). The paper finds `M ∈ (0,1)` empirically for matmul (§2.2), but
/// Add rescaling (App. A.2) can produce `M ≥ 1`.
#[inline]
pub fn multiply_by_quantized_multiplier_signed_shift(acc: i32, m0: i32, shift: i32) -> i32 {
    let left = shift.max(0);
    let right = (-shift).max(0);
    rounding_div_by_pot(srdhm(saturating_rounding_mul_by_pot(acc, left), m0), right)
}

/// A fixed-point value with `IB` integer bits and `31 - IB` fractional bits
/// stored in an `i32` (plus sign bit) — gemmlowp's `FixedPoint<tIntegerBits>`.
///
/// `Fp<0>` is Q0.31 covering (−1, 1); `Fp<5>` covers (−32, 32) etc. The
/// transcendental functions (App. A.1) are built from this type using only
/// integer arithmetic — "no lookup tables needed" (§2.1 eschews LUTs as they
/// perform poorly on SIMD hardware).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Fp<const IB: i32> {
    raw: i32,
}

impl<const IB: i32> Fp<IB> {
    pub const INTEGER_BITS: i32 = IB;
    pub const FRACTIONAL_BITS: i32 = 31 - IB;

    /// Wrap a raw integer as a fixed-point value (no scaling).
    #[inline]
    pub fn from_raw(raw: i32) -> Self {
        Self { raw }
    }

    #[inline]
    pub fn raw(self) -> i32 {
        self.raw
    }

    /// The value 1, saturated if `IB == 0` (Q0.31 cannot represent 1.0
    /// exactly; gemmlowp saturates to `i32::MAX` in that case).
    #[inline]
    pub fn one() -> Self {
        if IB == 0 {
            Self::from_raw(i32::MAX)
        } else {
            Self::from_raw(1i32 << Self::FRACTIONAL_BITS)
        }
    }

    #[inline]
    pub fn zero() -> Self {
        Self::from_raw(0)
    }

    /// The constant `2^exponent`, representable iff `-FRACTIONAL_BITS <=
    /// exponent < IB`.
    #[inline]
    pub fn constant_pot(exponent: i32) -> Self {
        let offset = Self::FRACTIONAL_BITS + exponent;
        debug_assert!(
            (0..31).contains(&offset),
            "2^{exponent} not representable with {IB} integer bits"
        );
        Self::from_raw(1i32 << offset)
    }

    /// Nearest fixed-point value to the real `x` (for building constants;
    /// never used on the inference hot path).
    pub fn from_f64(x: f64) -> Self {
        let scaled = x * 2f64.powi(Self::FRACTIONAL_BITS);
        Self::from_raw(scaled.round().clamp(f64::from(i32::MIN), f64::from(i32::MAX)) as i32)
    }

    /// The real value this fixed-point number represents (test/debug only).
    pub fn to_f64(self) -> f64 {
        f64::from(self.raw) / 2f64.powi(Self::FRACTIONAL_BITS)
    }

    /// Change the number of integer bits, preserving the represented value
    /// (gemmlowp `Rescale<tIntegerBitsDst>`).
    #[inline]
    pub fn rescale<const IB2: i32>(self) -> Fp<IB2> {
        let exponent = IB - IB2;
        Fp::<IB2>::from_raw(saturating_rounding_mul_by_pot(self.raw, exponent))
    }

    /// Saturating fixed-point addition.
    #[inline]
    pub fn add(self, rhs: Self) -> Self {
        Self::from_raw(self.raw.saturating_add(rhs.raw))
    }

    /// Saturating fixed-point subtraction.
    #[inline]
    pub fn sub(self, rhs: Self) -> Self {
        Self::from_raw(self.raw.saturating_sub(rhs.raw))
    }

    /// Same-type fixed-point product. Exact gemmlowp semantics for
    /// `FixedPoint<a> * FixedPoint<b>` require the output to carry `a+b`
    /// integer bits; for `IB == 0` (the transcendental hot case) the output
    /// type is unchanged, which is what this method implements. For mixed
    /// integer-bit products use [`Fp::mul_into`].
    #[inline]
    pub fn mul(self, rhs: Self) -> Fp<0>
    where
        Self: Sized,
    {
        // srdhm on raw values yields raw with IB_l + IB_r integer bits; the
        // caller re-interprets. For IB == 0 this is already Q0.31.
        Fp::<0>::from_raw(srdhm(self.raw, rhs.raw))
    }

    /// Fixed-point product producing a value with `IBO = IB + IB2` integer
    /// bits (checked with a debug assertion).
    #[inline]
    pub fn mul_into<const IB2: i32, const IBO: i32>(self, rhs: Fp<IB2>) -> Fp<IBO> {
        debug_assert!(IBO == IB + IB2, "fixed-point mul output must carry IB_lhs + IB_rhs integer bits");
        Fp::<IBO>::from_raw(srdhm(self.raw, rhs.raw))
    }

    /// Multiply by `2^exponent` with saturation.
    #[inline]
    pub fn mul_by_pot(self, exponent: i32) -> Self {
        Self::from_raw(saturating_rounding_mul_by_pot(self.raw, exponent))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srdhm_basic_products() {
        assert_eq!(srdhm(1 << 30, 1 << 30), 1 << 29); // 0.5 * 0.5 = 0.25
        assert_eq!(srdhm(i32::MAX, i32::MAX), i32::MAX - 1); // (~1.0)^2
        assert_eq!(srdhm(i32::MIN, i32::MIN), i32::MAX); // saturation case
        assert_eq!(srdhm(0, i32::MIN), 0);
        assert_eq!(srdhm(i32::MIN, i32::MAX), -i32::MAX); // -1.0 * ~1.0
    }

    #[test]
    fn srdhm_rounding_against_reference() {
        for &(a, b) in &[
            (123456789, 987654321),
            (-123456789, 987654321),
            (1 << 20, -(1 << 25)),
            (-7, 5),
            (3, 3),
            (i32::MAX, 1),
            (i32::MIN + 1, i32::MIN + 1),
        ] {
            let exact = i64::from(a) * i64::from(b);
            let nudge: i64 = if exact >= 0 { 1 << 30 } else { 1 - (1 << 30) };
            let want = ((exact + nudge) / (1i64 << 31)) as i32;
            assert_eq!(srdhm(a, b), want, "a={a} b={b}");
        }
    }

    #[test]
    fn rounding_div_ties_away_from_zero() {
        // The App. B example: -12 / 2^3 must be -2 (away from zero), not -1.
        assert_eq!(rounding_div_by_pot(-12, 3), -2);
        assert_eq!(rounding_div_by_pot(12, 3), 2);
        assert_eq!(rounding_div_by_pot(-11, 3), -1);
        assert_eq!(rounding_div_by_pot(11, 3), 1);
        assert_eq!(rounding_div_by_pot(-13, 3), -2);
        assert_eq!(rounding_div_by_pot(13, 3), 2);
        assert_eq!(rounding_div_by_pot(5, 0), 5);
        assert_eq!(rounding_div_by_pot(i32::MIN, 1), -(1 << 30));
    }

    #[test]
    fn rounding_div_matches_f64_rounding() {
        for x in [-1000i32, -999, -17, -1, 0, 1, 17, 999, 1000, 123456] {
            for e in 1..8 {
                let exact = f64::from(x) / 2f64.powi(e);
                let want = if (exact.fract()).abs() == 0.5 {
                    exact.trunc() + exact.signum()
                } else {
                    exact.round()
                } as i32;
                assert_eq!(rounding_div_by_pot(x, e), want, "x={x} e={e}");
            }
        }
    }

    #[test]
    fn saturating_pot_saturates() {
        assert_eq!(saturating_rounding_mul_by_pot(1 << 30, 2), i32::MAX);
        assert_eq!(saturating_rounding_mul_by_pot(-(1 << 30), 2), i32::MIN);
        assert_eq!(saturating_rounding_mul_by_pot(3, 2), 12);
        assert_eq!(saturating_rounding_mul_by_pot(12, -2), 3);
    }

    #[test]
    fn rounding_div_saturates_out_of_range_exponents() {
        // exponent ≥ 32 must produce the exact mathematical rounding in
        // debug AND release, not a mod-32-wrapped shift. Only
        // x = i32::MIN at exponent 32 reaches the −0.5 tie (away from
        // zero → −1); everything else rounds to 0.
        assert_eq!(rounding_div_by_pot(i32::MAX, 32), 0);
        assert_eq!(rounding_div_by_pot(i32::MAX, 63), 0);
        assert_eq!(rounding_div_by_pot(1, 40), 0);
        assert_eq!(rounding_div_by_pot(-1, 32), 0);
        assert_eq!(rounding_div_by_pot(0, 100), 0);
        assert_eq!(rounding_div_by_pot(i32::MIN, 32), -1);
        assert_eq!(rounding_div_by_pot(i32::MIN + 1, 32), 0);
        assert_eq!(rounding_div_by_pot(i32::MIN, 33), 0);
        // The in-range boundary is untouched: e = 31 still divides.
        assert_eq!(rounding_div_by_pot(i32::MAX, 31), 1);
        assert_eq!(rounding_div_by_pot(i32::MIN, 31), -1);
    }

    #[test]
    fn saturating_pot_handles_out_of_range_left_shifts() {
        assert_eq!(saturating_rounding_mul_by_pot(1, 32), i32::MAX);
        assert_eq!(saturating_rounding_mul_by_pot(-1, 40), i32::MIN);
        assert_eq!(saturating_rounding_mul_by_pot(0, 100), 0);
    }

    #[test]
    fn quantized_multiplier_primitive() {
        let m0 = 1 << 30; // 0.5 in Q0.31
        assert_eq!(multiply_by_quantized_multiplier(1000, m0, 0), 500);
        assert_eq!(multiply_by_quantized_multiplier(1000, m0, 1), 250);
        assert_eq!(multiply_by_quantized_multiplier(-1000, m0, 1), -250);
    }

    #[test]
    fn signed_shift_multiplier_handles_m_ge_1() {
        // M = 1.5 = 0.75 * 2^1 → m0 = 0.75 in Q0.31, shift = +1.
        let m0 = Fp::<0>::from_f64(0.75).raw();
        let got = multiply_by_quantized_multiplier_signed_shift(1000, m0, 1);
        assert_eq!(got, 1500);
    }

    #[test]
    fn fp_constants_and_rescale() {
        let one = Fp::<5>::one();
        assert!((one.to_f64() - 1.0).abs() < 1e-9);
        let half = Fp::<5>::constant_pot(-1);
        assert!((half.to_f64() - 0.5).abs() < 1e-9);
        let r: Fp<2> = half.rescale::<2>();
        assert!((r.to_f64() - 0.5).abs() < 1e-8);
    }

    #[test]
    fn fp_mul_is_accurate() {
        let a = Fp::<0>::from_f64(0.75);
        let b = Fp::<0>::from_f64(-0.5);
        let c = a.mul(b);
        assert!((c.to_f64() + 0.375).abs() < 1e-8, "{}", c.to_f64());
    }

    #[test]
    fn fp_from_to_f64_roundtrip() {
        for &x in &[0.0, 0.1, -0.9999, 0.5, -0.25] {
            let v = Fp::<0>::from_f64(x);
            assert!((v.to_f64() - x).abs() < 1e-8);
        }
        for &x in &[0.0, 1.0, -3.75, 15.9, -15.9] {
            let v = Fp::<4>::from_f64(x);
            assert!((v.to_f64() - x).abs() < 1e-6);
        }
    }
}
