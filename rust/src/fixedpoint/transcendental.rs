//! Pure fixed-point transcendental functions (paper App. A.1).
//!
//! "Math functions such as hyperbolic tangent, the logistic function, and
//! softmax often appear in neural networks. No lookup tables are needed
//! since these functions are implemented in pure fixed-point arithmetic" —
//! these are structural ports of the SIMD-ready, branch-free implementations
//! in gemmlowp's `fixedpoint` directory: a 4th-order Taylor core for
//! `exp` on `[-1/4, 0)`, a barrel shifter of precomputed `exp(-2^k)`
//! constants for the integer part, and Newton–Raphson division for the
//! rational forms of `tanh` and `logistic`.
//!
//! All functions take a [`Fp`] with `IB` integer bits and return `Fp<0>`
//! (Q0.31), matching gemmlowp's signatures. Accuracy is verified against
//! `f64` in the tests below and (via the quantized ops in [`crate::nn`])
//! against the JAX reference graphs.

use super::{srdhm, Fp};

/// Rounding half-sum `(a + b + 1) / 2` computed in 64-bit to avoid overflow
/// (gemmlowp `RoundingHalfSum`).
#[inline]
fn rounding_half_sum(a: i32, b: i32) -> i32 {
    ((i64::from(a) + i64::from(b) + 1) >> 1) as i32
}

/// `exp(x)` for `x ∈ [-1/4, 0)`, input and output Q0.31.
///
/// Computes `exp(-1/8) · exp(x + 1/8)` with a 4th-order Taylor expansion of
/// the second factor around 0, exactly as gemmlowp's
/// `exp_on_interval_between_negative_one_quarter_and_0_excl`.
fn exp_on_interval_neg_quarter_to_0(a: Fp<0>) -> Fp<0> {
    let constant_term = Fp::<0>::from_raw(1_895_147_668); // exp(-1/8) in Q0.31
    let constant_1_over_3 = Fp::<0>::from_raw(715_827_883); // 1/3 in Q0.31
    let x = a.add(Fp::<0>::constant_pot(-3)); // x = a + 1/8 ∈ [-1/8, 1/8)
    let x2 = x.mul(x);
    let x3 = x2.mul(x);
    let x4 = x2.mul(x2);
    let x4_over_4 = x4.mul_by_pot(-2);
    // ((x⁴/4 + x³)/3 + x²)/2 = x⁴/24 + x³/6 + x²/2
    let poly = x4_over_4.add(x3).mul(constant_1_over_3).add(x2).mul_by_pot(-1);
    constant_term.add(constant_term.mul(x.add(poly)))
}

/// `exp(a)` for `a ≤ 0`, with `IB` integer bits of input range.
///
/// Splits `a` into a multiple of 1/4 plus a remainder in `[-1/4, 0)`; the
/// remainder goes through the Taylor core, and each set bit of the integer
/// part multiplies in a precomputed `exp(-2^k)` Q0.31 constant (the "barrel
/// shifter"). Branch structure matches gemmlowp `exp_on_negative_values`.
pub fn exp_on_negative_values<const IB: i32>(a: Fp<IB>) -> Fp<0> {
    debug_assert!(a.raw() <= 0, "exp_on_negative_values requires a <= 0");
    let k_fractional_bits: i32 = 31 - IB;
    let one_quarter = Fp::<IB>::constant_pot(-2);
    let mask = one_quarter.raw() - 1;
    // a mod 1/4, shifted into [-1/4, 0).
    let a_mod_quarter_minus_one_quarter = (a.raw() & mask) - one_quarter.raw();
    let rescaled = Fp::<IB>::from_raw(a_mod_quarter_minus_one_quarter).rescale::<0>();
    let mut result = exp_on_interval_neg_quarter_to_0(rescaled);
    // The multiples of 1/4 we still owe: a_mod - a >= 0.
    let remainder = a_mod_quarter_minus_one_quarter.wrapping_sub(a.raw());

    // (exponent k, exp(-2^k) in Q0.31)
    const BARREL: [(i32, i32); 7] = [
        (-2, 1_672_461_947), // exp(-1/4)
        (-1, 1_302_514_674), // exp(-1/2)
        (0, 790_015_084),    // exp(-1)
        (1, 290_630_308),    // exp(-2)
        (2, 39_332_535),     // exp(-4)
        (3, 720_401),        // exp(-8)
        (4, 242),            // exp(-16)
    ];
    for (exponent, multiplier) in BARREL {
        if IB > exponent {
            let shift = k_fractional_bits + exponent;
            if (0..31).contains(&shift) && (remainder & (1i32 << shift)) != 0 {
                result = result.mul(Fp::<0>::from_raw(multiplier));
            }
        }
    }
    if IB > 5 {
        // Beyond -32 the result underflows Q0.31 entirely.
        let clamp_bound = -(1i64 << (k_fractional_bits + 5)).min(i64::from(i32::MAX)) as i32;
        if a.raw() < clamp_bound {
            result = Fp::<0>::zero();
        }
    }
    if a.raw() == 0 {
        Fp::<0>::one()
    } else {
        result
    }
}

/// Newton–Raphson reciprocal: returns `x ≈ 2 / (1 + a)` as `Fp<2>`, for
/// `a ∈ [0, 1)` Q0.31 (gemmlowp's core of `one_over_one_plus_x_for_x_in_0_1`).
fn two_over_one_plus_x(a: Fp<0>) -> Fp<2> {
    debug_assert!(a.raw() >= 0);
    // half_denominator = (1 + a) / 2 ∈ [1/2, 1), Q0.31.
    let half_denominator = Fp::<0>::from_raw(rounding_half_sum(a.raw(), i32::MAX));
    // Initial estimate x0 = 48/17 - 32/17 * d, the classic NR seed.
    let constant_48_over_17 = Fp::<2>::from_raw(1_515_870_810); // 48/17 in Q2.29
    let constant_neg_32_over_17 = Fp::<2>::from_raw(-1_010_580_540); // -32/17 in Q2.29
    // F0 * F2 product carries 2 integer bits: raw srdhm is correct Q2.29.
    let mut x = constant_48_over_17
        .add(Fp::<2>::from_raw(srdhm(half_denominator.raw(), constant_neg_32_over_17.raw())));
    for _ in 0..3 {
        let half_denominator_times_x = Fp::<2>::from_raw(srdhm(half_denominator.raw(), x.raw()));
        let one_minus = Fp::<2>::one().sub(half_denominator_times_x);
        // x * one_minus is Q4.27; rescale back to Q2.29 and accumulate.
        let delta = Fp::<4>::from_raw(srdhm(x.raw(), one_minus.raw())).rescale::<2>();
        x = x.add(delta);
    }
    x // ≈ 1 / half_denominator = 2 / (1 + a)
}

/// `1 / (1 + x)` for `x ∈ [0, 1)`, Q0.31 → Q0.31.
pub fn one_over_one_plus_x_for_x_in_0_1(a: Fp<0>) -> Fp<0> {
    let x = two_over_one_plus_x(a);
    // Halve (exact shift) then drop the integer bits: x/2 ∈ (1/2, 1].
    Fp::<2>::from_raw(x.raw()).mul_by_pot(-1).rescale::<0>()
}

/// `(1 - x) / (1 + x)` for `x ∈ [0, 1)`, Q0.31 → Q0.31 — the rational core
/// of `tanh` (gemmlowp `one_minus_x_over_one_plus_x_for_x_in_0_1`).
pub fn one_minus_x_over_one_plus_x_for_x_in_0_1(a: Fp<0>) -> Fp<0> {
    let x = two_over_one_plus_x(a);
    // 2/(1+a) - 1 = (1-a)/(1+a).
    x.sub(Fp::<2>::one()).rescale::<0>()
}

/// Hyperbolic tangent on fixed-point input: `tanh(a) = (1 - e^{-2a}) / (1 +
/// e^{-2a})` for `a ≥ 0`, odd-extended to negative inputs.
pub fn tanh<const IB: i32>(a: Fp<IB>) -> Fp<0> {
    let negative = a.raw() < 0;
    let abs_raw = if a.raw() == i32::MIN { i32::MAX } else { a.raw().abs() };
    // -2|a|, saturating.
    let minus_two_abs = Fp::<IB>::from_raw(abs_raw.saturating_neg()).mul_by_pot(1);
    let e = exp_on_negative_values(minus_two_abs);
    let t = one_minus_x_over_one_plus_x_for_x_in_0_1(e);
    if negative {
        Fp::<0>::from_raw(t.raw().saturating_neg())
    } else {
        t
    }
}

/// Logistic function `1 / (1 + e^{-a})` on fixed-point input, using
/// `logistic(-a) = 1 - logistic(a)` for negative inputs.
pub fn logistic<const IB: i32>(a: Fp<IB>) -> Fp<0> {
    let negative = a.raw() < 0;
    let abs_raw = if a.raw() == i32::MIN { i32::MAX } else { a.raw().abs() };
    let e = exp_on_negative_values(Fp::<IB>::from_raw(abs_raw.saturating_neg()));
    let p = one_over_one_plus_x_for_x_in_0_1(e);
    if negative {
        // 1 - p in Q0.31 (one() saturates to i32::MAX ≈ 1).
        Fp::<0>::from_raw(i32::MAX - p.raw())
    } else {
        p
    }
}

/// Rounding division of two int32s with round-to-nearest, used by the
/// quantized softmax to renormalize (`sum` is positive).
#[inline]
pub fn rounding_div(numerator: i64, denominator: i64) -> i32 {
    debug_assert!(denominator > 0);
    let half = denominator / 2;
    let n = if numerator >= 0 { numerator + half } else { numerator - half };
    (n / denominator) as i32
}

pub use super::Fp as FixedPoint;

#[cfg(test)]
mod tests {
    use super::*;

    fn check_exp<const IB: i32>(x: f64, tol: f64) {
        let a = Fp::<IB>::from_f64(x);
        let got = exp_on_negative_values(a).to_f64();
        let want = a.to_f64().exp();
        assert!((got - want).abs() < tol, "exp({x}) [IB={IB}]: got {got}, want {want}");
    }

    #[test]
    fn exp_matches_f64_ib0() {
        for i in 0..=100 {
            check_exp::<0>(-(i as f64) / 101.0, 3e-7);
        }
    }

    #[test]
    fn exp_matches_f64_ib5() {
        for i in 0..=100 {
            check_exp::<5>(-(i as f64) * 31.0 / 100.0, 2e-6);
        }
    }

    #[test]
    fn exp_at_zero_is_one() {
        assert_eq!(exp_on_negative_values(Fp::<5>::zero()).raw(), i32::MAX);
    }

    #[test]
    fn exp_is_monotonic() {
        let mut prev = -1.0;
        for i in (0..=1000).rev() {
            let a = Fp::<5>::from_f64(-(i as f64) * 20.0 / 1000.0);
            let v = exp_on_negative_values(a).to_f64();
            assert!(v >= prev, "exp not monotone at {}", a.to_f64());
            prev = v;
        }
    }

    #[test]
    fn reciprocal_matches_f64() {
        for i in 0..100 {
            let x = i as f64 / 100.0;
            let got = one_over_one_plus_x_for_x_in_0_1(Fp::<0>::from_f64(x)).to_f64();
            let want = 1.0 / (1.0 + x);
            assert!((got - want).abs() < 1e-6, "1/(1+{x}): got {got} want {want}");
        }
    }

    #[test]
    fn one_minus_over_one_plus_matches_f64() {
        for i in 0..100 {
            let x = i as f64 / 100.0;
            let got = one_minus_x_over_one_plus_x_for_x_in_0_1(Fp::<0>::from_f64(x)).to_f64();
            let want = (1.0 - x) / (1.0 + x);
            assert!((got - want).abs() < 1e-6, "(1-x)/(1+x) at {x}: got {got} want {want}");
        }
    }

    #[test]
    fn tanh_matches_f64() {
        for i in -80..=80 {
            let x = i as f64 / 10.0;
            let got = tanh(Fp::<4>::from_f64(x)).to_f64();
            let want = x.tanh();
            assert!((got - want).abs() < 2e-6, "tanh({x}): got {got} want {want}");
        }
    }

    #[test]
    fn tanh_is_odd() {
        for i in 1..50 {
            let x = i as f64 / 7.0;
            let p = tanh(Fp::<4>::from_f64(x)).raw();
            let n = tanh(Fp::<4>::from_f64(-x)).raw();
            assert_eq!(p, n.saturating_neg(), "tanh not odd at {x}");
        }
    }

    #[test]
    fn logistic_matches_f64() {
        for i in -80..=80 {
            let x = i as f64 / 10.0;
            let got = logistic(Fp::<4>::from_f64(x)).to_f64();
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((got - want).abs() < 2e-6, "logistic({x}): got {got} want {want}");
        }
    }

    #[test]
    fn logistic_symmetry() {
        // logistic(x) + logistic(-x) == 1 (up to 1 ulp of Q0.31).
        for i in 0..50 {
            let x = i as f64 / 5.0;
            let p = logistic(Fp::<4>::from_f64(x)).raw() as i64;
            let n = logistic(Fp::<4>::from_f64(-x)).raw() as i64;
            // Within a few Q0.31 ulps (~4e-9): the Newton-Raphson reciprocal
            // is not exactly symmetric around its fixed point.
            assert!((p + n - i64::from(i32::MAX)).abs() <= 8, "asymmetric at {x}");
        }
    }

    #[test]
    fn rounding_div_rounds_to_nearest() {
        assert_eq!(rounding_div(7, 2), 4); // 3.5 → away from zero
        assert_eq!(rounding_div(-7, 2), -4);
        assert_eq!(rounding_div(10, 3), 3);
        assert_eq!(rounding_div(11, 3), 4);
    }
}
