//! Minimal benchmarking harness for the `cargo bench` targets (the offline
//! build has no criterion). Reports min/median/p95/mean over timed
//! iterations after warmup, with enough repetitions for stable medians on
//! this single-core testbed.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub min_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub mean_us: f64,
}

impl Sample {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  min {:>10.1}us  median {:>10.1}us  p95 {:>10.1}us  mean {:>10.1}us",
            self.name, self.iters, self.min_us, self.median_us, self.p95_us, self.mean_us
        )
    }

    /// Median milliseconds (for ratio reporting).
    pub fn median_ms(&self) -> f64 {
        self.median_us / 1e3
    }
}

/// True when `IAOI_BENCH_SMOKE` is set: benches run a couple of iterations
/// per case instead of the full adaptive schedule. CI uses this to keep
/// bench code compiling and executing without paying measurement time;
/// numbers produced under smoke mode are *not* meaningful.
pub fn smoke_mode() -> bool {
    std::env::var_os("IAOI_BENCH_SMOKE").is_some()
}

/// Time `f` adaptively: at least `min_iters` iterations and at least
/// ~200 ms of total measurement, after 2 warmup calls. Under
/// [`smoke_mode`] the schedule collapses to at most 2 timed iterations.
pub fn bench(name: &str, min_iters: usize, mut f: impl FnMut()) -> Sample {
    f();
    f();
    let smoke = smoke_mode();
    let target_iters = if smoke { min_iters.clamp(1, 2) } else { min_iters };
    let mut times_us: Vec<f64> = Vec::new();
    let start = Instant::now();
    while times_us.len() < target_iters
        || (!smoke && start.elapsed().as_secs_f64() < 0.2)
    {
        let t = Instant::now();
        f();
        times_us.push(t.elapsed().as_secs_f64() * 1e6);
        if times_us.len() > 100_000 {
            break;
        }
    }
    let mut sorted = times_us.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
    let sample = Sample {
        name: name.to_string(),
        iters: sorted.len(),
        min_us: sorted[0],
        median_us: pick(0.5),
        p95_us: pick(0.95),
        mean_us: times_us.iter().sum::<f64>() / times_us.len() as f64,
    };
    println!("{}", sample.row());
    sample
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let s = bench("noop-spin", 50, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 50);
        assert!(s.min_us <= s.median_us);
        assert!(s.median_us <= s.p95_us);
    }
}
