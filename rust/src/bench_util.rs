//! Minimal benchmarking harness for the `cargo bench` targets (the offline
//! build has no criterion). Reports min/median/p95/mean over timed
//! iterations after warmup, with enough repetitions for stable medians on
//! this single-core testbed. Also hosts the shared armed counting
//! allocator ([`counting_alloc`]) used by the alloc regression test and
//! the model-load bench.

use std::time::Instant;

/// An armed counting [`std::alloc::GlobalAlloc`] wrapper shared by the
/// targets that need allocation accounting (`tests/alloc.rs` asserts on
/// event counts; `benches/model_load.rs` reports peak/total bytes). It is
/// NOT registered here — each target opts in with
/// `#[global_allocator] static GLOBAL: CountingAlloc = CountingAlloc;`
/// so ordinary builds keep the plain system allocator.
pub mod counting_alloc {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

    /// Pass-through system allocator that, while armed, counts allocation
    /// events and tracks net live bytes (signed: frees of pre-arm
    /// allocations may drive the net below the arming point), their peak,
    /// and the total bytes requested.
    pub struct CountingAlloc;

    static ARMED: AtomicBool = AtomicBool::new(false);
    static EVENTS: AtomicU64 = AtomicU64::new(0);
    static CURRENT: AtomicI64 = AtomicI64::new(0);
    static PEAK: AtomicI64 = AtomicI64::new(0);
    static TOTAL: AtomicU64 = AtomicU64::new(0);

    fn on_alloc(size: usize) {
        if ARMED.load(Ordering::Relaxed) {
            EVENTS.fetch_add(1, Ordering::Relaxed);
            let now = CURRENT.fetch_add(size as i64, Ordering::Relaxed) + size as i64;
            PEAK.fetch_max(now, Ordering::Relaxed);
            TOTAL.fetch_add(size as u64, Ordering::Relaxed);
        }
    }

    fn on_dealloc(size: usize) {
        if ARMED.load(Ordering::Relaxed) {
            CURRENT.fetch_sub(size as i64, Ordering::Relaxed);
        }
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            on_alloc(layout.size());
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            on_alloc(layout.size());
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            on_dealloc(layout.size());
            on_alloc(new_size);
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            on_dealloc(layout.size());
            System.dealloc(ptr, layout)
        }
    }

    /// What one armed measurement observed.
    #[derive(Clone, Copy, Debug)]
    pub struct Measure {
        /// Allocation events (alloc / alloc_zeroed / realloc).
        pub events: u64,
        /// Peak net live bytes above the arming point.
        pub peak_bytes: u64,
        /// Total bytes requested across all allocation events.
        pub total_bytes: u64,
    }

    /// Run `f` with the counter armed and return what it allocated. Only
    /// meaningful when [`CountingAlloc`] is the target's registered global
    /// allocator and nothing else allocates concurrently.
    pub fn measure(f: impl FnOnce()) -> Measure {
        EVENTS.store(0, Ordering::SeqCst);
        CURRENT.store(0, Ordering::SeqCst);
        PEAK.store(0, Ordering::SeqCst);
        TOTAL.store(0, Ordering::SeqCst);
        ARMED.store(true, Ordering::SeqCst);
        f();
        ARMED.store(false, Ordering::SeqCst);
        Measure {
            events: EVENTS.load(Ordering::SeqCst),
            peak_bytes: PEAK.load(Ordering::SeqCst).max(0) as u64,
            total_bytes: TOTAL.load(Ordering::SeqCst),
        }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub min_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub mean_us: f64,
}

impl Sample {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  min {:>10.1}us  median {:>10.1}us  p95 {:>10.1}us  mean {:>10.1}us",
            self.name, self.iters, self.min_us, self.median_us, self.p95_us, self.mean_us
        )
    }

    /// Median milliseconds (for ratio reporting).
    pub fn median_ms(&self) -> f64 {
        self.median_us / 1e3
    }
}

/// True when `IAOI_BENCH_SMOKE` is set: benches run a couple of iterations
/// per case instead of the full adaptive schedule. CI uses this to keep
/// bench code compiling and executing without paying measurement time;
/// numbers produced under smoke mode are *not* meaningful.
pub fn smoke_mode() -> bool {
    std::env::var_os("IAOI_BENCH_SMOKE").is_some()
}

/// Time `f` adaptively: at least `min_iters` iterations and at least
/// ~200 ms of total measurement, after 2 warmup calls. Under
/// [`smoke_mode`] the schedule collapses to at most 2 timed iterations.
pub fn bench(name: &str, min_iters: usize, mut f: impl FnMut()) -> Sample {
    f();
    f();
    let smoke = smoke_mode();
    let target_iters = if smoke { min_iters.clamp(1, 2) } else { min_iters };
    let mut times_us: Vec<f64> = Vec::new();
    let start = Instant::now();
    while times_us.len() < target_iters
        || (!smoke && start.elapsed().as_secs_f64() < 0.2)
    {
        let t = Instant::now();
        f();
        times_us.push(t.elapsed().as_secs_f64() * 1e6);
        if times_us.len() > 100_000 {
            break;
        }
    }
    let mut sorted = times_us.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
    let sample = Sample {
        name: name.to_string(),
        iters: sorted.len(),
        min_us: sorted[0],
        median_us: pick(0.5),
        p95_us: pick(0.95),
        mean_us: times_us.iter().sum::<f64>() / times_us.len() as f64,
    };
    println!("{}", sample.row());
    sample
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let s = bench("noop-spin", 50, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 50);
        assert!(s.min_us <= s.median_us);
        assert!(s.median_us <= s.p95_us);
    }
}
