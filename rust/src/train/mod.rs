//! QAT training driver (L3): feeds synthetic batches through the AOT
//! `train_step` artifact — the Rust binary *is* the trainer; Python only
//! authored and lowered the graph (Algorithm 1 steps 1–3, driven from Rust).
//!
//! The driver owns the full functional training state (parameters, SGD
//! momenta, BN EMA statistics, activation-range EMAs) as XLA literals in the
//! canonical order recorded in `model_spec.txt`, implements the paper's
//! *delayed activation quantization* by flipping the `act_quant_on` scalar
//! after `act_quant_delay` steps (§3.1), and exports folded weights
//! (eq. 14) plus learned ranges for the integer engine when training ends.

use crate::data::ClassificationSet;
use crate::graph::builders::ParamMap;
use crate::io;
use crate::runtime::{
    literal_f32, literal_i32, literal_scalar_f32, scalar_from_literal, tensor_from_literal, Engine,
};
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Parsed `model_spec.txt`.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub resolution: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub batch: usize,
    pub act_quant_delay: u64,
    pub param_keys: Vec<String>,
    pub bn_keys: Vec<String>,
    pub range_keys: Vec<String>,
    pub export_keys: Vec<String>,
}

impl ModelSpec {
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let kv = io::read_kv(&artifact_dir.join("model_spec.txt"))?;
        let get = |k: &str| -> Result<String> {
            kv.iter()
                .find(|(key, _)| key == k)
                .map(|(_, v)| v.clone())
                .ok_or_else(|| anyhow!("model_spec.txt missing key {k}"))
        };
        let list = |k: &str| -> Result<Vec<String>> {
            Ok(get(k)?.split(',').map(str::to_string).collect())
        };
        Ok(Self {
            resolution: get("resolution")?.parse()?,
            channels: get("channels")?.parse()?,
            num_classes: get("num_classes")?.parse()?,
            batch: get("batch")?.parse()?,
            act_quant_delay: get("act_quant_delay")?.parse()?,
            param_keys: list("param_keys")?,
            bn_keys: list("bn_keys")?,
            range_keys: list("range_keys")?,
            export_keys: list("export_keys")?,
        })
    }

    /// Total number of state tensors fed to / returned by `train_step`.
    pub fn state_len(&self) -> usize {
        2 * self.param_keys.len() + self.bn_keys.len() + self.range_keys.len()
    }
}

/// Quantization knobs fed to the compiled train/eval steps as traced
/// scalars (one artifact covers float baselines, ReLU/ReLU6 and the
/// bit-depth grid).
#[derive(Clone, Copy, Debug)]
pub struct Knobs {
    /// 1.0 = quantize weights (QAT); 0.0 = float baseline training.
    pub w_quant_on: f32,
    /// Activation ceiling: 6.0 = ReLU6, [`RELU_CEIL`] = plain ReLU.
    pub act_ceiling: f32,
    /// Weight bit depth (narrow range `[1, 2^bits - 1]`).
    pub weight_bits: u32,
    /// Activation bit depth (`[0, 2^bits - 1]`).
    pub act_bits: u32,
}

/// The "uncapped" ceiling standing in for plain ReLU.
pub const RELU_CEIL: f32 = 1e9;

impl Default for Knobs {
    fn default() -> Self {
        Self { w_quant_on: 1.0, act_ceiling: 6.0, weight_bits: 8, act_bits: 8 }
    }
}

impl Knobs {
    /// Float-baseline training (no quantization at all).
    pub fn float_baseline() -> Self {
        Self { w_quant_on: 0.0, ..Default::default() }
    }

    pub fn w_qmax(&self) -> f32 {
        ((1u32 << self.weight_bits) - 1) as f32
    }

    pub fn a_qmax(&self) -> f32 {
        ((1u32 << self.act_bits) - 1) as f32
    }
}

/// Training state as literals, in the canonical train_step order:
/// params ++ momenta ++ bn ++ ranges.
pub struct Trainer {
    pub spec: ModelSpec,
    engine: Engine,
    state: Vec<xla::Literal>,
    dataset: ClassificationSet,
    pub knobs: Knobs,
    pub step: u64,
    pub losses: Vec<f32>,
}

impl Trainer {
    /// Build a trainer from the artifact directory (spec + init params).
    pub fn new(artifact_dir: &Path, seed: u64) -> Result<Self> {
        let spec = ModelSpec::load(artifact_dir)?;
        let engine = Engine::new(artifact_dir)?;
        let init = io::read_params(&artifact_dir.join("params_init.bin"))?;
        let mut state = Vec::with_capacity(spec.state_len());
        for (prefix, keys) in [
            ("param", &spec.param_keys),
            ("mom", &spec.param_keys),
            ("bn", &spec.bn_keys),
            ("range", &spec.range_keys),
        ] {
            for key in keys {
                let name = format!("{prefix}:{key}");
                let t = init
                    .get(&name)
                    .ok_or_else(|| anyhow!("params_init.bin missing {name}"))?;
                state.push(literal_f32(t)?);
            }
        }
        let dataset = ClassificationSet::new(spec.resolution, spec.num_classes, seed);
        Ok(Self {
            spec,
            engine,
            state,
            dataset,
            knobs: Knobs::default(),
            step: 0,
            losses: Vec::new(),
        })
    }

    /// Set the quantization knobs for subsequent steps/evals.
    pub fn with_knobs(mut self, knobs: Knobs) -> Self {
        self.knobs = knobs;
        self
    }

    /// Generate the training batch for a step (deterministic in the seed).
    pub fn batch(&self, split: u64, step: u64) -> (Tensor<f32>, Vec<i32>) {
        let (x, labels) = self.dataset.batch(split, step * self.spec.batch as u64, self.spec.batch);
        (x, labels.into_iter().map(|l| l as i32).collect())
    }

    /// Run one QAT train step; returns the loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let (x, y) = self.batch(0, self.step);
        // §3.1 delayed activation quantization; forced off entirely for the
        // float baseline.
        let act_on = if self.knobs.w_quant_on > 0.0 && self.step >= self.spec.act_quant_delay {
            1.0
        } else {
            0.0
        };
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.state.len() + 7);
        // Literal has no cheap clone in the xla crate; rebuild inputs by
        // draining and restoring state from outputs below.
        inputs.append(&mut self.state);
        inputs.push(literal_f32(&x)?);
        inputs.push(literal_i32(&y, &[y.len() as i64])?);
        inputs.push(literal_scalar_f32(act_on));
        inputs.push(literal_scalar_f32(self.knobs.w_quant_on));
        inputs.push(literal_scalar_f32(self.knobs.act_ceiling));
        inputs.push(literal_scalar_f32(self.knobs.w_qmax()));
        inputs.push(literal_scalar_f32(self.knobs.a_qmax()));
        let mut outs = self.engine.run("train_step.hlo.txt", &inputs)?;
        let loss_lit = outs.pop().ok_or_else(|| anyhow!("train_step returned nothing"))?;
        anyhow::ensure!(outs.len() == self.spec.state_len(), "train_step output arity");
        self.state = outs;
        let loss = scalar_from_literal(&loss_lit)?;
        self.losses.push(loss);
        self.step += 1;
        Ok(loss)
    }

    fn params_and_bn(&self) -> (usize, usize) {
        (self.spec.param_keys.len(), self.spec.bn_keys.len())
    }

    /// Clone a slice of the state as fresh literals (via host roundtrip).
    fn state_slice(&self, lo: usize, hi: usize) -> Result<Vec<xla::Literal>> {
        self.state[lo..hi]
            .iter()
            .map(|l| literal_f32(&tensor_from_literal(l)?))
            .collect()
    }

    /// Evaluate accuracy with the float graph (`eval_float.hlo.txt`).
    pub fn eval_float(&mut self, batches: usize) -> Result<f32> {
        self.eval(batches, false)
    }

    /// Evaluate accuracy with the quantization-simulation graph
    /// (`eval_qsim.hlo.txt`, the L1 Pallas fake-quant path).
    pub fn eval_qsim(&mut self, batches: usize) -> Result<f32> {
        self.eval(batches, true)
    }

    fn eval(&mut self, batches: usize, qsim: bool) -> Result<f32> {
        let (np, nb) = self.params_and_bn();
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..batches {
            let (x, y) = self.batch(1, b as u64);
            let mut inputs = self.state_slice(0, np)?; // params
            inputs.extend(self.state_slice(2 * np, 2 * np + nb)?); // bn
            if qsim {
                inputs.extend(self.state_slice(2 * np + nb, self.spec.state_len())?); // ranges
            }
            inputs.push(literal_f32(&x)?);
            inputs.push(literal_scalar_f32(self.knobs.act_ceiling));
            if qsim {
                inputs.push(literal_scalar_f32(self.knobs.w_qmax()));
                inputs.push(literal_scalar_f32(self.knobs.a_qmax()));
            }
            let name = if qsim { "eval_qsim.hlo.txt" } else { "eval_float.hlo.txt" };
            let outs = self.engine.run(name, &inputs)?;
            let logits = tensor_from_literal(&outs[0])?;
            let classes = logits.dim(1);
            for (row, &label) in y.iter().enumerate() {
                let data = &logits.data()[row * classes..(row + 1) * classes];
                let argmax = data
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += usize::from(argmax == label as usize);
                total += 1;
            }
        }
        Ok(correct as f32 / total as f32)
    }

    /// Export folded inference weights (eq. 14) via `export_fold.hlo.txt`.
    pub fn export_folded(&mut self) -> Result<ParamMap> {
        let (np, nb) = self.params_and_bn();
        let mut inputs = self.state_slice(0, np)?;
        inputs.extend(self.state_slice(2 * np, 2 * np + nb)?);
        let outs = self.engine.run("export_fold.hlo.txt", &inputs)?;
        anyhow::ensure!(outs.len() == self.spec.export_keys.len(), "export arity");
        let mut map = ParamMap::new();
        for (key, lit) in self.spec.export_keys.iter().zip(&outs) {
            map.insert(key.clone(), tensor_from_literal(lit)?);
        }
        Ok(map)
    }

    /// The learned activation ranges (name, (min, max)) from the EMA state.
    pub fn learned_ranges(&self) -> Result<Vec<(String, (f64, f64))>> {
        let (np, nb) = self.params_and_bn();
        let lo = 2 * np + nb;
        let mut out = Vec::new();
        for (i, key) in self.spec.range_keys.iter().enumerate() {
            let t = tensor_from_literal(&self.state[lo + i])?;
            out.push((key.clone(), (f64::from(t.data()[0]), f64::from(t.data()[1]))));
        }
        Ok(out)
    }

    /// Persist the trained state (params + ranges, folded weights) to disk.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        let folded = self.export_folded()?;
        let mut tensors: Vec<(String, Tensor<f32>)> = folded.into_iter().collect();
        tensors.sort_by(|a, b| a.0.cmp(&b.0));
        for (key, (mn, mx)) in self.learned_ranges()? {
            tensors.push((format!("range:{key}"), Tensor::from_vec(&[2], vec![mn as f32, mx as f32])));
        }
        io::write_params(path, &tensors).context("save trained model")
    }
}
