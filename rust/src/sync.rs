//! Poison-recovering lock helpers.
//!
//! A panicking thread poisons any `Mutex`/`RwLock` it holds, and the
//! default `.lock().expect(...)` response turns one contained fault into a
//! cascade: every other worker that touches the lock panics too, which is
//! exactly the failure mode a fault-contained server must not have. The
//! shared state behind the serving-side locks — metrics maps, batch
//! queues, admission tables, the model registry, connection lists — is
//! either plain counters or values replaced wholesale while the lock is
//! held, so the "data may be inconsistent" signal that poisoning carries
//! is never actionable here: recovering the guard is always better than
//! killing the process.
//!
//! Every shared lock in `coordinator/` and `serve/` goes through these
//! helpers; new code should too.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Read-lock `l`, recovering the guard if a writer panicked.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// Write-lock `l`, recovering the guard if a previous holder panicked.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn poisoned_mutex_recovers_with_data_intact() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 42;
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned by the panic");
        assert_eq!(*lock_recover(&m), 42);
        // Recovering does not clear the poison flag; it just keeps working.
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 43);
    }

    #[test]
    fn poisoned_rwlock_recovers_for_both_readers_and_writers() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.read().is_err(), "rwlock should be poisoned by the panic");
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }
}
