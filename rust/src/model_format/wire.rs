//! Little-endian wire primitives for the `.iaoiq` artifact format: a
//! growable [`Writer`] and a bounds-checked, never-panicking [`Reader`].
//!
//! Both directions are total functions over their inputs. The writer
//! returns a structured [`EncodeError`] when a field cannot be represented
//! (a string longer than its `u16` length prefix, a slice count or tensor
//! dimension past `u32`, a tensor rank past the wire limit) instead of
//! asserting. The reader reports [`DecodeError::Truncated`] with the offset
//! and the number of bytes it needed, and [`DecodeError::BadCount`] — with
//! the declared element count and the **exact byte need computed in
//! `u64`** — when a count-prefixed field declares more data than the buffer
//! holds, so corrupt or cut-off files fail with a precise diagnostic
//! instead of a panic, an unbounded allocation, or a need that was silently
//! truncated through `usize` arithmetic.

use super::{DecodeError, EncodeError};
use crate::quant::QuantParams;
use crate::tensor::{ArtifactBytes, Tensor};

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u32` count-prefixed f64 vector (per-channel scale vectors).
    pub fn put_f64_slice(&mut self, v: &[f64]) -> Result<(), EncodeError> {
        let count = Self::check_u32("f64 slice length", v.len())?;
        self.put_u32(count);
        for &x in v {
            self.put_f64(x);
        }
        Ok(())
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// `u16` length-prefixed UTF-8.
    pub fn put_str(&mut self, s: &str) -> Result<(), EncodeError> {
        if s.len() > usize::from(u16::MAX) {
            return Err(EncodeError::TooLarge {
                what: "string",
                len: s.len() as u64,
                max: u64::from(u16::MAX),
            });
        }
        self.put_u16(s.len() as u16);
        self.put_bytes(s.as_bytes());
        Ok(())
    }

    pub fn put_quant_params(&mut self, p: &QuantParams) {
        self.put_bytes(&p.to_wire());
    }

    /// Rank-prefixed shape followed by the raw element bytes.
    pub fn put_u8_tensor(&mut self, t: &Tensor<u8>) -> Result<(), EncodeError> {
        if t.rank() > 8 {
            return Err(EncodeError::TooLarge {
                what: "tensor rank",
                len: t.rank() as u64,
                max: 8,
            });
        }
        self.put_u8(t.rank() as u8);
        for &d in t.shape() {
            let d = Self::check_u32("tensor dimension", d)?;
            self.put_u32(d);
        }
        self.put_bytes(t.data());
        Ok(())
    }

    /// `u32` count-prefixed i32 vector (biases).
    pub fn put_i32_slice(&mut self, v: &[i32]) -> Result<(), EncodeError> {
        let count = Self::check_u32("i32 slice length", v.len())?;
        self.put_u32(count);
        for &x in v {
            self.put_i32(x);
        }
        Ok(())
    }

    fn check_u32(what: &'static str, v: usize) -> Result<u32, EncodeError> {
        u32::try_from(v).map_err(|_| EncodeError::TooLarge {
            what,
            len: v as u64,
            max: u64::from(u32::MAX),
        })
    }
}

/// Bounds-checked little-endian decoder over a borrowed buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Current byte offset (for error reporting).
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Bytes left to read — callers use this to bound count-prefixed
    /// allocations before reserving capacity.
    pub fn remaining_bytes(&self) -> usize {
        self.remaining()
    }

    /// The unread tail of the buffer, without consuming it (the checksum
    /// verification peeks at the whole payload before decoding it).
    pub fn remaining_slice(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take `n` raw bytes or fail with a [`DecodeError::Truncated`] carrying
    /// the exact offset/need.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Truncated { offset: self.pos, needed: n });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Guard a count-prefixed field: `count` elements of `width` bytes must
    /// fit in the remaining buffer. The byte need is computed in `u64`, so
    /// it is exact even where `count × width` would overflow `usize` —
    /// the error carries honest numbers instead of `usize::MAX`.
    fn check_count(
        &self,
        what: &'static str,
        count: u64,
        width: u32,
    ) -> Result<usize, DecodeError> {
        let needed = count.saturating_mul(u64::from(width));
        if needed > self.remaining() as u64 {
            return Err(DecodeError::BadCount {
                offset: self.pos,
                what,
                count,
                width,
                remaining: self.remaining() as u64,
            });
        }
        Ok(needed as usize)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32, DecodeError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Count-prefixed f64 vector; the count is bounded against the bytes
    /// actually remaining before anything is allocated.
    pub fn f64_slice(&mut self) -> Result<Vec<f64>, DecodeError> {
        let count = self.u32()?;
        let bytes = self.check_count("f64 slice", u64::from(count), 8)?;
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn str(&mut self) -> Result<String, DecodeError> {
        let len = usize::from(self.u16()?);
        let offset = self.pos;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError::BadUtf8 { offset })
    }

    pub fn quant_params(&mut self) -> Result<QuantParams, DecodeError> {
        let bytes: &[u8; QuantParams::WIRE_BYTES] =
            self.take(QuantParams::WIRE_BYTES)?.try_into().unwrap();
        Ok(QuantParams::from_wire(bytes))
    }

    /// Decode a tensor, copying its elements to the heap.
    pub fn u8_tensor(&mut self) -> Result<Tensor<u8>, DecodeError> {
        self.u8_tensor_with(None)
    }

    /// Decode a tensor. With `backing = Some(buf)` — which must be the
    /// buffer this reader was constructed over, so reader offsets are
    /// buffer offsets — element storage of
    /// [`super::ZERO_COPY_MIN_BYTES`]-or-more bytes becomes a zero-copy
    /// view into the buffer; smaller tensors (and all tensors when
    /// `backing` is `None`) are copied to the heap.
    pub fn u8_tensor_with(
        &mut self,
        backing: Option<&ArtifactBytes>,
    ) -> Result<Tensor<u8>, DecodeError> {
        let rank = usize::from(self.u8()?);
        if rank > 8 {
            return Err(DecodeError::BadEnum { what: "tensor rank", value: rank as u8 });
        }
        let mut shape = Vec::with_capacity(rank);
        let mut volume: u64 = 1;
        for _ in 0..rank {
            let d = u64::from(self.u32()?);
            volume = volume.saturating_mul(d);
            shape.push(d as usize);
        }
        // Bound the allocation by the bytes actually present; the need is
        // reported exactly (in u64) rather than truncated through usize.
        let bytes = self.check_count("tensor elements", volume, 1)?;
        match backing {
            Some(buf) if bytes >= super::ZERO_COPY_MIN_BYTES => {
                debug_assert!(std::ptr::eq(buf.as_slice().as_ptr(), self.buf.as_ptr()));
                let offset = self.pos;
                self.take(bytes)?;
                Ok(Tensor::from_view(&shape, buf.view(offset, bytes)))
            }
            _ => {
                let data = self.take(bytes)?.to_vec();
                Ok(Tensor::from_vec(&shape, data))
            }
        }
    }

    pub fn i32_slice(&mut self) -> Result<Vec<i32>, DecodeError> {
        let count = self.u32()?;
        let bytes = self.check_count("i32 slice", u64::from(count), 4)?;
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Assert the buffer is fully consumed.
    pub fn finish(self) -> Result<(), DecodeError> {
        if self.remaining() != 0 {
            return Err(DecodeError::TrailingBytes { extra: self.remaining() });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u16(300);
        w.put_u32(70_000);
        w.put_u64(u64::MAX - 5);
        w.put_i32(-5);
        w.put_str("hello").unwrap();
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), u64::MAX - 5);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.str().unwrap(), "hello");
        r.finish().unwrap();
    }

    #[test]
    fn truncation_reports_offset_and_need() {
        let mut w = Writer::new();
        w.put_u32(1);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..2]);
        match r.u32() {
            Err(DecodeError::Truncated { offset: 0, needed: 4 }) => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tensor_roundtrip_and_oversized_dims_rejected() {
        let t = Tensor::from_vec(&[2, 3], (0..6u8).collect::<Vec<_>>());
        let mut w = Writer::new();
        w.put_u8_tensor(&t).unwrap();
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8_tensor().unwrap(), t);
        r.finish().unwrap();

        // A huge declared volume must fail fast without allocating, and the
        // reported need must be the honest u64 product, not usize::MAX.
        let mut w = Writer::new();
        w.put_u8(2);
        w.put_u32(u32::MAX);
        w.put_u32(u32::MAX);
        let bytes = w.into_bytes();
        match Reader::new(&bytes).u8_tensor() {
            Err(DecodeError::BadCount { count, width: 1, remaining: 0, .. }) => {
                assert_eq!(count, u64::from(u32::MAX) * u64::from(u32::MAX));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn zero_copy_tensor_views_share_the_buffer() {
        let t = Tensor::from_vec(&[4, 32], (0..128u8).collect::<Vec<_>>());
        let mut w = Writer::new();
        w.put_u8(9); // displace the tensor so its offset is non-zero
        w.put_u8_tensor(&t).unwrap();
        let buf = ArtifactBytes::from_vec(w.into_bytes());
        let mut r = Reader::new(buf.as_slice());
        r.u8().unwrap();
        let view = r.u8_tensor_with(Some(&buf)).unwrap();
        r.finish().unwrap();
        assert!(view.is_view(), "128 bytes is past the zero-copy threshold");
        assert_eq!(view, t, "views decode the same contents");
        // The copy path decodes identically.
        let mut r = Reader::new(buf.as_slice());
        r.u8().unwrap();
        let copied = r.u8_tensor().unwrap();
        assert!(!copied.is_view());
        assert_eq!(copied, view);
    }

    #[test]
    fn small_tensors_are_copied_even_with_backing() {
        let t = Tensor::from_vec(&[4], vec![1u8, 2, 3, 4]);
        let mut w = Writer::new();
        w.put_u8_tensor(&t).unwrap();
        let buf = ArtifactBytes::from_vec(w.into_bytes());
        let mut r = Reader::new(buf.as_slice());
        let small = r.u8_tensor_with(Some(&buf)).unwrap();
        assert!(!small.is_view(), "below the threshold the copy path wins");
        assert_eq!(small, t);
    }

    #[test]
    fn i32_slice_roundtrip() {
        let v = vec![1, -2, i32::MAX, i32::MIN];
        let mut w = Writer::new();
        w.put_i32_slice(&v).unwrap();
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.i32_slice().unwrap(), v);
    }

    #[test]
    fn f64_slice_roundtrip_and_bounded() {
        let v = vec![0.5, -1.25, 1e-300, f64::MAX];
        let mut w = Writer::new();
        w.put_f64_slice(&v).unwrap();
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.f64_slice().unwrap(), v);
        r.finish().unwrap();

        // A huge declared count must fail fast without allocating, with the
        // exact byte need (count × 8) in the diagnostic.
        let mut w = Writer::new();
        w.put_u32(u32::MAX);
        let bytes = w.into_bytes();
        match Reader::new(&bytes).f64_slice() {
            Err(DecodeError::BadCount { count, width: 8, remaining: 0, .. }) => {
                assert_eq!(count, u64::from(u32::MAX));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn oversized_writer_inputs_are_structured_errors() {
        let mut w = Writer::new();
        let long = "x".repeat(usize::from(u16::MAX) + 1);
        assert_eq!(
            w.put_str(&long).unwrap_err(),
            EncodeError::TooLarge {
                what: "string",
                len: u64::from(u16::MAX) + 1,
                max: u64::from(u16::MAX)
            }
        );
        let t9: Tensor<u8> = Tensor::zeros(&[1, 1, 1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(
            w.put_u8_tensor(&t9).unwrap_err(),
            EncodeError::TooLarge { what: "tensor rank", len: 9, max: 8 }
        );
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = Writer::new();
        w.put_u8(1);
        let mut bytes = w.into_bytes();
        bytes.push(0);
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert!(matches!(r.finish(), Err(DecodeError::TrailingBytes { extra: 1 })));
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut w = Writer::new();
        w.put_u16(2);
        w.put_bytes(&[0xff, 0xfe]);
        let bytes = w.into_bytes();
        assert!(matches!(Reader::new(&bytes).str(), Err(DecodeError::BadUtf8 { .. })));
    }
}
