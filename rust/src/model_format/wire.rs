//! Little-endian wire primitives for the `.iaoiq` artifact format: a
//! growable [`Writer`] and a bounds-checked, never-panicking [`Reader`].
//!
//! The reader reports [`DecodeError::Truncated`] with the offset and the
//! number of bytes it needed, so corrupt or cut-off files fail with a
//! precise diagnostic instead of a panic or an unbounded allocation: every
//! variable-length field is checked against the bytes actually remaining
//! before anything is allocated.

use super::DecodeError;
use crate::quant::QuantParams;
use crate::tensor::Tensor;

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u32` count-prefixed f64 vector (per-channel scale vectors).
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        assert!(v.len() <= u32::MAX as usize);
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_f64(x);
        }
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// `u16` length-prefixed UTF-8. Names longer than 64 KiB are a caller
    /// bug, not a data condition.
    pub fn put_str(&mut self, s: &str) {
        assert!(s.len() <= usize::from(u16::MAX), "name too long for u16 length prefix");
        self.put_u16(s.len() as u16);
        self.put_bytes(s.as_bytes());
    }

    pub fn put_quant_params(&mut self, p: &QuantParams) {
        self.put_bytes(&p.to_wire());
    }

    /// Rank-prefixed shape followed by the raw element bytes.
    pub fn put_u8_tensor(&mut self, t: &Tensor<u8>) {
        assert!(t.rank() <= 8, "tensor rank exceeds wire limit");
        self.put_u8(t.rank() as u8);
        for &d in t.shape() {
            assert!(d <= u32::MAX as usize);
            self.put_u32(d as u32);
        }
        self.put_bytes(t.data());
    }

    /// `u32` count-prefixed i32 vector (biases).
    pub fn put_i32_slice(&mut self, v: &[i32]) {
        assert!(v.len() <= u32::MAX as usize);
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_i32(x);
        }
    }
}

/// Bounds-checked little-endian decoder over a borrowed buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Current byte offset (for error reporting).
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Bytes left to read — callers use this to bound count-prefixed
    /// allocations before reserving capacity.
    pub fn remaining_bytes(&self) -> usize {
        self.remaining()
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take `n` raw bytes or fail with a [`DecodeError::Truncated`] carrying
    /// the exact offset/need.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Truncated { offset: self.pos, needed: n });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32, DecodeError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Count-prefixed f64 vector; the count is bounded against the bytes
    /// actually remaining before anything is allocated.
    pub fn f64_slice(&mut self) -> Result<Vec<f64>, DecodeError> {
        let count = self.u32()? as usize;
        let bytes = count.checked_mul(8).unwrap_or(usize::MAX);
        if bytes > self.remaining() {
            return Err(DecodeError::Truncated { offset: self.pos, needed: bytes });
        }
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn str(&mut self) -> Result<String, DecodeError> {
        let len = usize::from(self.u16()?);
        let offset = self.pos;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError::BadUtf8 { offset })
    }

    pub fn quant_params(&mut self) -> Result<QuantParams, DecodeError> {
        let bytes: &[u8; QuantParams::WIRE_BYTES] =
            self.take(QuantParams::WIRE_BYTES)?.try_into().unwrap();
        Ok(QuantParams::from_wire(bytes))
    }

    pub fn u8_tensor(&mut self) -> Result<Tensor<u8>, DecodeError> {
        let rank = usize::from(self.u8()?);
        if rank > 8 {
            return Err(DecodeError::BadEnum { what: "tensor rank", value: rank as u8 });
        }
        let mut shape = Vec::with_capacity(rank);
        let mut volume: u64 = 1;
        for _ in 0..rank {
            let d = u64::from(self.u32()?);
            volume = volume.saturating_mul(d);
            shape.push(d as usize);
        }
        // Bound the allocation by the bytes actually present.
        if volume > self.remaining() as u64 {
            return Err(DecodeError::Truncated { offset: self.pos, needed: volume as usize });
        }
        let data = self.take(volume as usize)?.to_vec();
        Ok(Tensor::from_vec(&shape, data))
    }

    pub fn i32_slice(&mut self) -> Result<Vec<i32>, DecodeError> {
        let count = self.u32()? as usize;
        let bytes = count.checked_mul(4).unwrap_or(usize::MAX);
        if bytes > self.remaining() {
            return Err(DecodeError::Truncated { offset: self.pos, needed: bytes });
        }
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Assert the buffer is fully consumed.
    pub fn finish(self) -> Result<(), DecodeError> {
        if self.remaining() != 0 {
            return Err(DecodeError::TrailingBytes { extra: self.remaining() });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u16(300);
        w.put_u32(70_000);
        w.put_i32(-5);
        w.put_str("hello");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.str().unwrap(), "hello");
        r.finish().unwrap();
    }

    #[test]
    fn truncation_reports_offset_and_need() {
        let mut w = Writer::new();
        w.put_u32(1);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..2]);
        match r.u32() {
            Err(DecodeError::Truncated { offset: 0, needed: 4 }) => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tensor_roundtrip_and_oversized_dims_rejected() {
        let t = Tensor::from_vec(&[2, 3], (0..6u8).collect::<Vec<_>>());
        let mut w = Writer::new();
        w.put_u8_tensor(&t);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8_tensor().unwrap(), t);
        r.finish().unwrap();

        // A huge declared volume must fail fast without allocating.
        let mut w = Writer::new();
        w.put_u8(2);
        w.put_u32(u32::MAX);
        w.put_u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(matches!(
            Reader::new(&bytes).u8_tensor(),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn i32_slice_roundtrip() {
        let v = vec![1, -2, i32::MAX, i32::MIN];
        let mut w = Writer::new();
        w.put_i32_slice(&v);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.i32_slice().unwrap(), v);
    }

    #[test]
    fn f64_slice_roundtrip_and_bounded() {
        let v = vec![0.5, -1.25, 1e-300, f64::MAX];
        let mut w = Writer::new();
        w.put_f64_slice(&v);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.f64_slice().unwrap(), v);
        r.finish().unwrap();

        // A huge declared count must fail fast without allocating.
        let mut w = Writer::new();
        w.put_u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(matches!(Reader::new(&bytes).f64_slice(), Err(DecodeError::Truncated { .. })));
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = Writer::new();
        w.put_u8(1);
        let mut bytes = w.into_bytes();
        bytes.push(0);
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert!(matches!(r.finish(), Err(DecodeError::TrailingBytes { extra: 1 })));
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut w = Writer::new();
        w.put_u16(2);
        w.put_bytes(&[0xff, 0xfe]);
        let bytes = w.into_bytes();
        assert!(matches!(Reader::new(&bytes).str(), Err(DecodeError::BadUtf8 { .. })));
    }
}
