//! The `.iaoiq` quantized-model artifact format: a self-describing binary
//! serialization of a complete integer-only [`QGraph`] — the repo's
//! counterpart of the TFLite flatbuffer the paper deploys through gemmlowp.
//! A model is quantized once (PTQ or QAT export), written to disk, and from
//! then on every serving process loads the artifact directly; reloading is
//! lossless, so inference from a loaded graph is **bit-identical** to the
//! in-memory original.
//!
//! ## Layout (version 3, all little-endian)
//!
//! ```text
//! magic        b"IAOQ"                                    4 bytes
//! version      u32                                        currently 3
//! checksum     u64                                        v3+: FNV-1a 64 over
//!                                                         every following byte
//! name         u16 len + utf-8                            registry model name
//! model_ver    u32                                        registry version
//! input_shape  u32 × 3                                    H, W, C of one example
//! kernel       u8                                         GEMM kernel code
//! input_qp     QuantParams wire                           20 bytes (f64 scale,
//!                                                         i32 zp/qmin/qmax)
//! node_count   u32
//! node × count:
//!   name       u16 len + utf-8
//!   input      u32                                        0xFFFF_FFFF = graph
//!                                                         input, else node idx
//!   op_code    u8                                         see table below
//!   payload    op-specific (see `encode_op`)
//! ```
//!
//! Op codes: 0 conv2d, 1 depthwise, 2 fully-connected, 3 avg-pool,
//! 4 max-pool, 5 global-avg-pool, 6 add, 7 concat, 8 softmax, 9 logistic.
//! Conv-like payloads carry the uint8 weight tensor, the weight
//! quantization, the int32 bias vector (eq. 11), stride/padding, the
//! fused-activation code, and the normalized requantization multiplier(s)
//! `2^shift · M0` (eq. 5–6). The multipliers are redundant with the stored
//! scales; the loader recomputes and rejects the file on mismatch, so
//! bit-rot in any of the fields is caught at load time.
//!
//! **Version 2** (append-only): the conv-like weight-quantization field
//! starts with a mode byte — 0 = per-tensor followed by the classic
//! 20-byte [`QuantParams`], 1 = per-channel followed by `zero_point`,
//! `qmin`, `qmax` (i32 each) and a count-prefixed f64 scale vector
//! (one scale per output channel, Krishnamoorthi 1806.08342) — and the
//! trailing multiplier block carries one `(m0, shift)` pair per channel.
//! Version 1 artifacts (no mode byte, always per-tensor, single
//! multiplier) still decode bit-identically; `rust/tests/model_format.rs`
//! pins a golden v1 blob.
//!
//! **Version 3** (header-only change): an FNV-1a 64 checksum of the whole
//! payload (everything after the checksum field) sits between the version
//! and the name. It is verified *when present* — v1/v2 artifacts carry
//! none and still load — so a torn write or bit-rotted file fails at
//! install/swap time with [`DecodeError::ChecksumMismatch`] instead of
//! serving corrupt weights (or tripping the deeper multiplier integrity
//! check with a less actionable message).
//!
//! ## Load modes
//!
//! [`load`] copies every weight tensor out of the byte stream — simple,
//! and the right call when the caller's buffer is transient. The zero-copy
//! path, [`load_shared`], decodes from a shared [`ArtifactBytes`] buffer
//! (heap, or an `mmap` of the artifact file) and hands out weight tensors
//! that *borrow* the buffer ([`Tensor::from_view`]) for every u8 tensor of
//! [`ZERO_COPY_MIN_BYTES`]-or-more bytes; small or non-u8 fields (i32
//! biases, f64 scale vectors — unaligned in the stream) are still eagerly
//! copied. Loading a model this way allocates `o(weight bytes)` instead of
//! a second full copy of the weights, and the loaded graph keeps the
//! buffer alive through its views. [`LoadMode`] names the three file-level
//! strategies ([`read_file_with`]); the `IAOI_LOAD` environment variable
//! picks the default for [`read_file`], so the whole test suite can run
//! under any mode.
//!
//! Decoding is fully bounds-checked ([`wire::Reader`]) and never panics or
//! over-allocates on corrupt input; every failure is a structured
//! [`DecodeError`]. Encoding is total as well: [`save`] returns a
//! structured [`EncodeError`] for graphs that cannot be represented
//! (non-finite requantization multipliers from degenerate scales,
//! length-prefix overflow) instead of panicking.

pub mod wire;

use crate::gemm::Kernel;
use crate::graph::{NodeRef, QGraph, QNode, QOp};
use crate::nn::conv::QConv2d;
use crate::nn::depthwise::QDepthwiseConv2d;
use crate::nn::fc::QFullyConnected;
use crate::nn::{FusedActivation, Padding};
use crate::quant::{ChannelQuantParams, QuantParams, QuantizedMultiplier, WeightQuant};
use crate::tensor::ArtifactBytes;
use anyhow::{Context, Result};
use std::fmt;
use std::path::Path;
use wire::{Reader, Writer};

/// File magic.
pub const MAGIC: &[u8; 4] = b"IAOQ";
/// Current format version (v3 = header payload checksum; v2 = per-channel
/// weight scales; v1/v2 artifacts still load).
pub const FORMAT_VERSION: u32 = 3;
/// Canonical file extension (without the dot).
pub const EXTENSION: &str = "iaoiq";
/// Byte offset where the checksummed payload begins in a v3+ artifact:
/// magic (4) + version (4) + checksum (8).
pub const PAYLOAD_OFFSET: usize = 16;
/// Minimum element-byte size at which [`load_shared`] hands out a borrowed
/// view instead of a heap copy. Below this, the copy is cheaper than the
/// per-view `Arc` bookkeeping and the view's pin on the whole buffer.
pub const ZERO_COPY_MIN_BYTES: usize = 64;

/// FNV-1a 64 over `bytes` — the v3 header checksum. Dependency-free and
/// fast enough that install/swap verification is noise next to the decode
/// itself.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        hash ^= u64::from(b);
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// Recompute and overwrite the header checksum of a v3+ artifact buffer in
/// place; a no-op for buffers that carry no checksum (wrong magic, v1/v2,
/// or too short). Corruption *tests* use this to reach the validation
/// stages behind the checksum; production code never needs it — artifacts
/// are written once by [`save`], which stamps the correct value.
pub fn restamp_checksum(bytes: &mut [u8]) {
    if bytes.len() < PAYLOAD_OFFSET || &bytes[..4] != MAGIC {
        return;
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version < 3 {
        return;
    }
    let sum = checksum(&bytes[PAYLOAD_OFFSET..]);
    bytes[8..PAYLOAD_OFFSET].copy_from_slice(&sum.to_le_bytes());
}

/// How [`read_file_with`] materializes artifact bytes, and whether the
/// decoded graph owns or borrows its weight storage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoadMode {
    /// Read the file, copy every tensor out of the stream (the historical
    /// behaviour; the decode transiently holds ~2× the weight bytes).
    #[default]
    Copy,
    /// Read the file into a shared heap buffer and borrow large weight
    /// tensors from it ([`load_shared`]).
    ZeroCopy,
    /// `mmap` the file read-only and borrow large weight tensors from the
    /// mapping; falls back to [`Self::ZeroCopy`]'s heap buffer where
    /// mapping is unavailable ([`ArtifactBytes::map_file`]).
    Mmap,
}

impl LoadMode {
    /// Parse a CLI label (`copy` | `zerocopy` | `mmap`).
    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "copy" => Some(Self::Copy),
            "zerocopy" => Some(Self::ZeroCopy),
            "mmap" => Some(Self::Mmap),
            _ => None,
        }
    }

    /// The default mode: the `IAOI_LOAD` environment variable when it names
    /// a mode, else [`Self::Copy`]. CI runs the suite under each value so
    /// both storage paths stay covered. An *unrecognized* value falls back
    /// to copy but warns on stderr — a typo in the override must not
    /// silently turn a storage-coverage run into a second copy-mode run.
    pub fn from_env() -> Self {
        match std::env::var("IAOI_LOAD") {
            Ok(v) => Self::from_label(&v).unwrap_or_else(|| {
                eprintln!(
                    "warning: IAOI_LOAD={v:?} is not a load mode (copy | zerocopy | mmap); \
                     defaulting to copy"
                );
                Self::Copy
            }),
            Err(_) => Self::Copy,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Copy => "copy",
            Self::ZeroCopy => "zerocopy",
            Self::Mmap => "mmap",
        }
    }
}

const INPUT_REF: u32 = u32::MAX;

/// Weight-quantization mode byte (v2+, append-only).
const WQ_PER_TENSOR: u8 = 0;
const WQ_PER_CHANNEL: u8 = 1;

const OP_CONV: u8 = 0;
const OP_DEPTHWISE: u8 = 1;
const OP_FC: u8 = 2;
const OP_AVG_POOL: u8 = 3;
const OP_MAX_POOL: u8 = 4;
const OP_GLOBAL_AVG_POOL: u8 = 5;
const OP_ADD: u8 = 6;
const OP_CONCAT: u8 = 7;
const OP_SOFTMAX: u8 = 8;
const OP_LOGISTIC: u8 = 9;

/// Structured decode failure: what went wrong and where.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The buffer ended before a field: `needed` more bytes at `offset`.
    Truncated { offset: usize, needed: usize },
    /// A count-prefixed field declares more elements than the remaining
    /// bytes could hold: `count × width` bytes needed (computed in `u64`,
    /// so the number is exact rather than clamped to `usize::MAX`) with
    /// only `remaining` left at `offset`.
    BadCount { offset: usize, what: &'static str, count: u64, width: u32, remaining: u64 },
    /// The v3 header checksum does not match the payload — the file is
    /// torn (partial write) or bit-rotted.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// First four bytes are not [`MAGIC`].
    BadMagic { found: [u8; 4] },
    /// Format version newer than this build understands.
    UnsupportedVersion { found: u32, supported: u32 },
    /// A length-prefixed string is not UTF-8.
    BadUtf8 { offset: usize },
    /// Unknown op code on a node.
    BadOpCode { node: usize, code: u8 },
    /// An enum field (padding, activation, kernel, rank) holds an unknown
    /// code.
    BadEnum { what: &'static str, value: u8 },
    /// A node references the graph input sentinel incorrectly or a node
    /// that is not strictly earlier in the DAG.
    BadNodeRef { node: usize, reference: u32 },
    /// A header field fails semantic validation (empty model name, zero
    /// input dimension, bad graph-input quant params).
    InvalidHeader { what: &'static str },
    /// A node field decoded but fails semantic validation (shape arity,
    /// bias length, non-positive scale, zero stride, …).
    InvalidField { node: usize, what: &'static str },
    /// Nodes decoded individually but the graph fails whole-topology
    /// validation; carries the validator's description.
    InvalidGraph { detail: String },
    /// The stored requantization multiplier does not match the one derived
    /// from the stored scales (eq. 5) — the file is corrupt.
    MultiplierMismatch { node: usize },
    /// Well-formed artifact followed by junk bytes.
    TrailingBytes { extra: usize },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Truncated { offset, needed } => {
                write!(f, "truncated artifact: needed {needed} more bytes at offset {offset}")
            }
            DecodeError::BadCount { offset, what, count, width, remaining } => {
                write!(
                    f,
                    "bad count at offset {offset}: {what} declares {count} element(s) of \
                     {width} byte(s) ({} bytes) but only {remaining} bytes remain",
                    count.saturating_mul(u64::from(*width))
                )
            }
            DecodeError::ChecksumMismatch { stored, computed } => {
                write!(
                    f,
                    "artifact checksum mismatch: header says {stored:#018x}, payload hashes \
                     to {computed:#018x} — the file is torn or bit-rotted"
                )
            }
            DecodeError::BadMagic { found } => {
                write!(f, "bad magic {found:?} (expected {MAGIC:?}) — not an .iaoiq artifact")
            }
            DecodeError::UnsupportedVersion { found, supported } => {
                write!(f, "artifact format version {found} is newer than supported version {supported}")
            }
            DecodeError::BadUtf8 { offset } => write!(f, "non-UTF-8 name at offset {offset}"),
            DecodeError::BadOpCode { node, code } => {
                write!(f, "node {node}: unknown op code {code}")
            }
            DecodeError::BadEnum { what, value } => write!(f, "unknown {what} code {value}"),
            DecodeError::BadNodeRef { node, reference } => {
                write!(f, "node {node}: reference {reference} is not an earlier node")
            }
            DecodeError::InvalidHeader { what } => write!(f, "invalid artifact header: {what}"),
            DecodeError::InvalidField { node, what } => write!(f, "node {node}: invalid {what}"),
            DecodeError::InvalidGraph { detail } => write!(f, "invalid graph: {detail}"),
            DecodeError::MultiplierMismatch { node } => {
                write!(f, "node {node}: stored requantization multiplier disagrees with stored scales")
            }
            DecodeError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after a complete artifact")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Structured encode failure: why a graph cannot be serialized. [`save`]
/// returns these instead of panicking, so a degenerate graph (or an
/// absurdly-sized field) surfaces as a clean CLI error from `iaoi export`
/// rather than an abort.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EncodeError {
    /// A conv-like node's requantization multiplier, derived from its
    /// stored scales (eq. 5), is not finite and positive — the scales are
    /// degenerate (zero, negative, infinite, or NaN) and no integrity-
    /// checkable multiplier block can be written for the node.
    NonFiniteMultiplier { node: usize },
    /// A field exceeds its wire length prefix (`len` vs the format's
    /// `max`): string past `u16`, slice count / tensor dimension / node
    /// count past `u32`, tensor rank past 8, node index colliding with the
    /// graph-input sentinel.
    TooLarge { what: &'static str, len: u64, max: u64 },
    /// An artifact header field fails semantic validation (zero input
    /// shape dimension).
    InvalidArtifact { what: &'static str },
}

impl fmt::Display for EncodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EncodeError::NonFiniteMultiplier { node } => {
                write!(
                    f,
                    "node {node}: requantization multiplier derived from the stored scales \
                     is not finite and positive; the graph's quantization parameters are \
                     degenerate and cannot be serialized"
                )
            }
            EncodeError::TooLarge { what, len, max } => {
                write!(f, "{what} of {len} exceeds the wire format's maximum of {max}")
            }
            EncodeError::InvalidArtifact { what } => write!(f, "invalid artifact: {what}"),
        }
    }
}

impl std::error::Error for EncodeError {}

/// A serialized-model unit: the quantized graph plus the registry metadata
/// ([`crate::coordinator::registry`]) that names and versions it.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    /// Registry name (non-empty).
    pub name: String,
    /// Monotonic model version — bumped on each retrain/hot-swap.
    pub version: u32,
    /// Shape `[H, W, C]` of one input example (batch dim excluded).
    pub input_shape: [usize; 3],
    /// The integer-only graph.
    pub graph: QGraph,
    /// The shared byte buffer the graph's zero-copy weight views borrow
    /// from — `Some` only for [`load_shared`]-decoded artifacts. The views
    /// themselves keep the buffer alive; this handle makes the dependency
    /// visible to owners (the registry stores it on each entry) and lets
    /// them report whether a resident model is file-mapped.
    pub backing: Option<ArtifactBytes>,
}

impl ModelArtifact {
    pub fn new(
        name: impl Into<String>,
        version: u32,
        input_shape: [usize; 3],
        graph: QGraph,
    ) -> Self {
        let name = name.into();
        assert!(!name.is_empty(), "artifact name must be non-empty");
        Self { name, version, input_shape, graph, backing: None }
    }

    /// The batched NHWC input shape for a batch of `n`.
    pub fn batched_shape(&self, n: usize) -> [usize; 4] {
        [n, self.input_shape[0], self.input_shape[1], self.input_shape[2]]
    }

    /// Build the prepared execution plan for serving this artifact —
    /// load → prepare is the deployment path ([`crate::coordinator::registry`]
    /// does this at install/swap time): weights are packed and output stages
    /// built once here, never per request. Prepared inference is
    /// bit-identical to running [`Self::graph`] directly.
    pub fn prepare(&self) -> crate::graph::PreparedGraph {
        self.graph.prepare()
    }

    /// [`Self::prepare`] with an explicit [`crate::gemm::PrepareMode`] —
    /// `Lazy` defers per-layer panel packing to first touch, packing
    /// straight from this artifact's mapped backing when loaded zero-copy.
    pub fn prepare_with(&self, mode: crate::gemm::PrepareMode) -> crate::graph::PreparedGraph {
        self.graph.prepare_with(mode)
    }
}

/// The eq. 5 requantization multiplier(s) of a conv-like node, normalized
/// for integer application: one per output channel in per-channel mode,
/// one total otherwise. `None` when a scale combination is degenerate
/// (possible only in corrupt files; valid converters always produce
/// positive finite scales).
fn requant_multipliers(
    weight: &WeightQuant,
    input: &QuantParams,
    output: &QuantParams,
) -> Option<Vec<QuantizedMultiplier>> {
    let rows = weight.channels().unwrap_or(1);
    (0..rows)
        .map(|ch| {
            let m = weight.scale(ch) * input.scale / output.scale;
            if m.is_finite() && m > 0.0 {
                Some(QuantizedMultiplier::from_f64(m))
            } else {
                None
            }
        })
        .collect()
}

/// Encode a conv-like node's weight quantization (v2 layout: mode byte then
/// the mode-specific parameter block).
fn encode_weight_quant(w: &mut Writer, wq: &WeightQuant) -> Result<(), EncodeError> {
    match wq {
        WeightQuant::PerTensor(p) => {
            w.put_u8(WQ_PER_TENSOR);
            w.put_quant_params(p);
        }
        WeightQuant::PerChannel(c) => {
            w.put_u8(WQ_PER_CHANNEL);
            w.put_i32(c.zero_point);
            w.put_i32(c.qmin);
            w.put_i32(c.qmax);
            w.put_f64_slice(&c.scales)?;
        }
    }
    Ok(())
}

/// Decode a conv-like node's weight quantization. Version 1 files carry a
/// bare per-tensor [`QuantParams`] with no mode byte.
fn decode_weight_quant(
    r: &mut Reader,
    node: usize,
    version: u32,
) -> Result<WeightQuant, DecodeError> {
    if version < 2 {
        return Ok(WeightQuant::PerTensor(decode_quant_params(r, node, "weight quant params")?));
    }
    let mode = r.u8()?;
    match mode {
        WQ_PER_TENSOR => {
            Ok(WeightQuant::PerTensor(decode_quant_params(r, node, "weight quant params")?))
        }
        WQ_PER_CHANNEL => {
            let zero_point = r.i32()?;
            let qmin = r.i32()?;
            let qmax = r.i32()?;
            let scales = r.f64_slice()?;
            let c = ChannelQuantParams { scales, zero_point, qmin, qmax };
            if c.wire_valid() {
                Ok(WeightQuant::PerChannel(c))
            } else {
                Err(DecodeError::InvalidField { node, what: "per-channel weight quant params" })
            }
        }
        other => Err(DecodeError::BadEnum { what: "weight quant mode", value: other }),
    }
}

/// Encode the trailing multiplier block: one `(m0, shift)` pair per output
/// channel (a single pair in per-tensor mode). A graph whose scales yield a
/// non-finite or non-positive multiplier cannot be serialized — the decoder
/// would reject it anyway — so this reports [`EncodeError`] instead of
/// panicking on it.
fn encode_multipliers(
    w: &mut Writer,
    node: usize,
    wq: &WeightQuant,
    input: &QuantParams,
    output: &QuantParams,
) -> Result<(), EncodeError> {
    let ms = requant_multipliers(wq, input, output)
        .ok_or(EncodeError::NonFiniteMultiplier { node })?;
    for m in ms {
        w.put_i32(m.m0);
        w.put_i32(m.shift);
    }
    Ok(())
}

fn encode_ref(w: &mut Writer, r: NodeRef) -> Result<(), EncodeError> {
    match r {
        NodeRef::Input => w.put_u32(INPUT_REF),
        NodeRef::Node(i) => {
            if i as u64 >= u64::from(INPUT_REF) {
                return Err(EncodeError::TooLarge {
                    what: "node index",
                    len: i as u64,
                    max: u64::from(INPUT_REF) - 1,
                });
            }
            w.put_u32(i as u32);
        }
    }
    Ok(())
}

fn decode_ref(raw: u32, node: usize) -> Result<NodeRef, DecodeError> {
    if raw == INPUT_REF {
        return Ok(NodeRef::Input);
    }
    if (raw as usize) < node {
        Ok(NodeRef::Node(raw as usize))
    } else {
        Err(DecodeError::BadNodeRef { node, reference: raw })
    }
}

fn decode_quant_params(
    r: &mut Reader,
    node: usize,
    what: &'static str,
) -> Result<QuantParams, DecodeError> {
    let p = r.quant_params()?;
    if p.wire_valid() {
        Ok(p)
    } else {
        Err(DecodeError::InvalidField { node, what })
    }
}

fn encode_op(w: &mut Writer, node: usize, op: &QOp) -> Result<(), EncodeError> {
    match op {
        QOp::Conv(c) => {
            w.put_u8(OP_CONV);
            w.put_u8_tensor(&c.weights)?;
            encode_weight_quant(w, &c.weight_quant)?;
            w.put_i32_slice(&c.bias)?;
            w.put_u32(c.stride as u32);
            w.put_u8(c.padding.code());
            w.put_quant_params(&c.input_params);
            w.put_quant_params(&c.output_params);
            w.put_u8(c.activation.code());
            encode_multipliers(w, node, &c.weight_quant, &c.input_params, &c.output_params)?;
        }
        QOp::Depthwise(d) => {
            w.put_u8(OP_DEPTHWISE);
            w.put_u8_tensor(&d.weights)?;
            encode_weight_quant(w, &d.weight_quant)?;
            w.put_i32_slice(&d.bias)?;
            w.put_u32(d.stride as u32);
            w.put_u8(d.padding.code());
            w.put_quant_params(&d.input_params);
            w.put_quant_params(&d.output_params);
            w.put_u8(d.activation.code());
            encode_multipliers(w, node, &d.weight_quant, &d.input_params, &d.output_params)?;
        }
        QOp::Fc(fc) => {
            w.put_u8(OP_FC);
            w.put_u8_tensor(&fc.weights)?;
            encode_weight_quant(w, &fc.weight_quant)?;
            w.put_i32_slice(&fc.bias)?;
            w.put_quant_params(&fc.input_params);
            w.put_quant_params(&fc.output_params);
            w.put_u8(fc.activation.code());
            encode_multipliers(w, node, &fc.weight_quant, &fc.input_params, &fc.output_params)?;
        }
        QOp::AvgPool { kernel, stride, padding } => {
            w.put_u8(OP_AVG_POOL);
            w.put_u32(*kernel as u32);
            w.put_u32(*stride as u32);
            w.put_u8(padding.code());
        }
        QOp::MaxPool { kernel, stride, padding } => {
            w.put_u8(OP_MAX_POOL);
            w.put_u32(*kernel as u32);
            w.put_u32(*stride as u32);
            w.put_u8(padding.code());
        }
        QOp::GlobalAvgPool => w.put_u8(OP_GLOBAL_AVG_POOL),
        QOp::Add { other, out_params } => {
            w.put_u8(OP_ADD);
            encode_ref(w, *other)?;
            w.put_quant_params(out_params);
        }
        QOp::Concat { others, out_params } => {
            w.put_u8(OP_CONCAT);
            if others.len() > u32::MAX as usize {
                return Err(EncodeError::TooLarge {
                    what: "concat operand count",
                    len: others.len() as u64,
                    max: u64::from(u32::MAX),
                });
            }
            w.put_u32(others.len() as u32);
            for r in others {
                encode_ref(w, *r)?;
            }
            w.put_quant_params(out_params);
        }
        QOp::Softmax => w.put_u8(OP_SOFTMAX),
        QOp::Logistic => w.put_u8(OP_LOGISTIC),
    }
    Ok(())
}

/// Decode the conv-like common tail: stride, padding, the activation-side
/// parameter sets, activation, and the integrity-checked multiplier block
/// (one `(m0, shift)` pair per output channel).
struct ConvTail {
    stride: usize,
    padding: Padding,
    input_params: QuantParams,
    output_params: QuantParams,
    activation: FusedActivation,
}

fn decode_conv_tail(
    r: &mut Reader,
    node: usize,
    weight_quant: &WeightQuant,
    with_geometry: bool,
) -> Result<ConvTail, DecodeError> {
    let (stride, padding) = if with_geometry {
        let stride = r.u32()? as usize;
        if stride == 0 {
            return Err(DecodeError::InvalidField { node, what: "stride" });
        }
        let pad_code = r.u8()?;
        let padding = Padding::from_code(pad_code)
            .ok_or(DecodeError::BadEnum { what: "padding", value: pad_code })?;
        (stride, padding)
    } else {
        (1, Padding::Same)
    };
    let input_params = decode_quant_params(r, node, "input quant params")?;
    let output_params = decode_quant_params(r, node, "output quant params")?;
    let act_code = r.u8()?;
    let activation = FusedActivation::from_code(act_code)
        .ok_or(DecodeError::BadEnum { what: "activation", value: act_code })?;
    let derived = requant_multipliers(weight_quant, &input_params, &output_params)
        .ok_or(DecodeError::InvalidField { node, what: "requant multiplier" })?;
    for d in derived {
        let stored = QuantizedMultiplier { m0: r.i32()?, shift: r.i32()? };
        if stored != d {
            return Err(DecodeError::MultiplierMismatch { node });
        }
    }
    Ok(ConvTail { stride, padding, input_params, output_params, activation })
}

/// Per-channel scale vectors must be one-per-output-channel; `channels` is
/// the op's channel dimension from the decoded weight tensor.
fn check_weight_channels(
    wq: &WeightQuant,
    channels: usize,
    node: usize,
) -> Result<(), DecodeError> {
    match wq.channels() {
        Some(c) if c != channels => {
            Err(DecodeError::InvalidField { node, what: "per-channel scale count" })
        }
        _ => Ok(()),
    }
}

fn decode_op(
    r: &mut Reader,
    node: usize,
    version: u32,
    backing: Option<&ArtifactBytes>,
) -> Result<QOp, DecodeError> {
    let code = r.u8()?;
    match code {
        OP_CONV => {
            let weights = r.u8_tensor_with(backing)?;
            if weights.rank() != 4 {
                return Err(DecodeError::InvalidField { node, what: "conv weight rank" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(0), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(0) {
                return Err(DecodeError::InvalidField { node, what: "conv bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, true)?;
            Ok(QOp::Conv(QConv2d {
                weights,
                weight_quant,
                bias,
                stride: tail.stride,
                padding: tail.padding,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_DEPTHWISE => {
            let weights = r.u8_tensor_with(backing)?;
            if weights.rank() != 4 || weights.dim(0) != 1 {
                return Err(DecodeError::InvalidField { node, what: "depthwise weight shape" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(3), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(3) {
                return Err(DecodeError::InvalidField { node, what: "depthwise bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, true)?;
            Ok(QOp::Depthwise(QDepthwiseConv2d {
                weights,
                weight_quant,
                bias,
                stride: tail.stride,
                padding: tail.padding,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_FC => {
            let weights = r.u8_tensor_with(backing)?;
            if weights.rank() != 2 {
                return Err(DecodeError::InvalidField { node, what: "fc weight rank" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(0), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(0) {
                return Err(DecodeError::InvalidField { node, what: "fc bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, false)?;
            Ok(QOp::Fc(QFullyConnected {
                weights,
                weight_quant,
                bias,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_AVG_POOL | OP_MAX_POOL => {
            let kernel = r.u32()? as usize;
            let stride = r.u32()? as usize;
            if kernel == 0 || stride == 0 {
                return Err(DecodeError::InvalidField { node, what: "pool geometry" });
            }
            let pad_code = r.u8()?;
            let padding = Padding::from_code(pad_code)
                .ok_or(DecodeError::BadEnum { what: "padding", value: pad_code })?;
            Ok(if code == OP_AVG_POOL {
                QOp::AvgPool { kernel, stride, padding }
            } else {
                QOp::MaxPool { kernel, stride, padding }
            })
        }
        OP_GLOBAL_AVG_POOL => Ok(QOp::GlobalAvgPool),
        OP_ADD => {
            let other = decode_ref(r.u32()?, node)?;
            let out_params = decode_quant_params(r, node, "add output quant params")?;
            Ok(QOp::Add { other, out_params })
        }
        OP_CONCAT => {
            let count = r.u32()?;
            // Each ref is 4 bytes; bound before allocating, with the exact
            // u64 byte need in the diagnostic.
            if u64::from(count) * 4 > r.remaining_bytes() as u64 {
                return Err(DecodeError::BadCount {
                    offset: r.offset(),
                    what: "concat operand refs",
                    count: u64::from(count),
                    width: 4,
                    remaining: r.remaining_bytes() as u64,
                });
            }
            let count = count as usize;
            let mut others = Vec::with_capacity(count);
            for _ in 0..count {
                others.push(decode_ref(r.u32()?, node)?);
            }
            let out_params = decode_quant_params(r, node, "concat output quant params")?;
            Ok(QOp::Concat { others, out_params })
        }
        OP_SOFTMAX => Ok(QOp::Softmax),
        OP_LOGISTIC => Ok(QOp::Logistic),
        other => Err(DecodeError::BadOpCode { node, code: other }),
    }
}

/// Serialize an artifact to bytes. Total order of fields is documented in
/// the module header; the encoding is deterministic, so equal graphs yield
/// byte-equal artifacts (used by tests as a losslessness oracle). Fails
/// with a structured [`EncodeError`] — never panics — on graphs the wire
/// format cannot carry (degenerate requantization scales, length-prefix
/// overflow).
pub fn save(artifact: &ModelArtifact) -> Result<Vec<u8>, EncodeError> {
    // Single buffer: the checksum field is written as a placeholder and
    // patched once the payload bytes exist, so encoding never holds a
    // second copy of the artifact (the same transient this module's
    // zero-copy *load* path eliminates).
    let mut p = Writer::new();
    p.put_bytes(MAGIC);
    p.put_u32(FORMAT_VERSION);
    p.put_u64(0); // checksum placeholder, patched below
    p.put_str(&artifact.name)?;
    p.put_u32(artifact.version);
    for &d in &artifact.input_shape {
        if d == 0 {
            return Err(EncodeError::InvalidArtifact { what: "zero input shape dimension" });
        }
        if d > u32::MAX as usize {
            return Err(EncodeError::TooLarge {
                what: "input shape dimension",
                len: d as u64,
                max: u64::from(u32::MAX),
            });
        }
        p.put_u32(d as u32);
    }
    p.put_u8(artifact.graph.kernel.code());
    p.put_quant_params(&artifact.graph.input_params);
    if artifact.graph.nodes.len() > u32::MAX as usize {
        return Err(EncodeError::TooLarge {
            what: "node count",
            len: artifact.graph.nodes.len() as u64,
            max: u64::from(u32::MAX),
        });
    }
    p.put_u32(artifact.graph.nodes.len() as u32);
    for (idx, node) in artifact.graph.nodes.iter().enumerate() {
        p.put_str(&node.name)?;
        encode_ref(&mut p, node.input)?;
        encode_op(&mut p, idx, &node.op)?;
    }
    let mut bytes = p.into_bytes();
    let sum = checksum(&bytes[PAYLOAD_OFFSET..]);
    bytes[8..PAYLOAD_OFFSET].copy_from_slice(&sum.to_le_bytes());
    Ok(bytes)
}

/// Deserialize an artifact, validating structure, enums, DAG ordering, and
/// the per-layer multiplier integrity check. Never panics on corrupt input.
/// Every weight tensor is copied out of `bytes`; see [`load_shared`] for
/// the zero-copy path.
pub fn load(bytes: &[u8]) -> Result<ModelArtifact, DecodeError> {
    load_impl(bytes, None)
}

/// [`load`] from a shared buffer: large u8 weight tensors borrow `buf`
/// ([`crate::tensor::Tensor::from_view`]) instead of owning copies, so the
/// decode allocates `o(weight bytes)` and the returned graph (plus
/// [`ModelArtifact::backing`]) keeps `buf` alive. Inference from the
/// borrowed graph is bit-identical to a copy-loaded one — storage is the
/// only difference.
pub fn load_shared(buf: &ArtifactBytes) -> Result<ModelArtifact, DecodeError> {
    load_impl(buf.as_slice(), Some(buf))
}

fn load_impl(bytes: &[u8], backing: Option<&ArtifactBytes>) -> Result<ModelArtifact, DecodeError> {
    let mut r = Reader::new(bytes);
    let magic: [u8; 4] = r.take(4)?.try_into().unwrap();
    if &magic != MAGIC {
        return Err(DecodeError::BadMagic { found: magic });
    }
    let version = r.u32()?;
    if version > FORMAT_VERSION {
        return Err(DecodeError::UnsupportedVersion { found: version, supported: FORMAT_VERSION });
    }
    // Verify the payload checksum when the format carries one (v3+): torn
    // or bit-rotted files fail here, before any structure is trusted.
    if version >= 3 {
        let stored = r.u64()?;
        let computed = checksum(r.remaining_slice());
        if stored != computed {
            return Err(DecodeError::ChecksumMismatch { stored, computed });
        }
    }
    let name = r.str()?;
    if name.is_empty() {
        return Err(DecodeError::InvalidHeader { what: "empty model name" });
    }
    let model_version = r.u32()?;
    let mut input_shape = [0usize; 3];
    for d in &mut input_shape {
        *d = r.u32()? as usize;
        if *d == 0 {
            return Err(DecodeError::InvalidHeader { what: "zero input shape dimension" });
        }
    }
    let kernel_code = r.u8()?;
    let kernel = Kernel::from_code(kernel_code)
        .ok_or(DecodeError::BadEnum { what: "gemm kernel", value: kernel_code })?;
    let input_params = r.quant_params()?;
    if !input_params.wire_valid() {
        return Err(DecodeError::InvalidHeader { what: "graph input quant params" });
    }
    let node_count = r.u32()? as usize;
    let mut nodes: Vec<QNode> = Vec::new();
    for idx in 0..node_count {
        let node_name = r.str()?;
        let input = decode_ref(r.u32()?, idx)?;
        let op = decode_op(&mut r, idx, version, backing)?;
        nodes.push(QNode { name: node_name, input, op });
    }
    r.finish()?;
    let graph = QGraph { input_params, nodes, kernel };
    // Belt-and-braces: decode_ref already enforces backward references, but
    // run the graph-level validator so the invariant has a single source of
    // truth shared with other producers.
    if let Err(detail) = graph.validate_topology() {
        return Err(DecodeError::InvalidGraph { detail });
    }
    Ok(ModelArtifact {
        name,
        version: model_version,
        input_shape,
        graph,
        backing: backing.cloned(),
    })
}

/// Write an artifact file (conventionally `<anything>.iaoiq`).
/// Returns the encoded bytes so callers that want to verify or reuse them
/// (export's readback check) don't pay a second encode.
///
/// The write is atomic: bytes land in a sibling temp file that is then
/// renamed over `path`. Rewriting a path in place would truncate the inode
/// a live [`LoadMode::Mmap`] serving process may still have mapped — a
/// SIGBUS on its next cold page — whereas a rename leaves the old inode
/// intact until its mappings drop, which is what makes export-then-swap
/// onto the same path safe under every load mode.
pub fn write_file(path: &Path, artifact: &ModelArtifact) -> Result<Vec<u8>> {
    let bytes = save(artifact).with_context(|| format!("encode artifact {path:?}"))?;
    let tmp = path.with_extension(format!("{EXTENSION}.tmp-{}", std::process::id()));
    if let Err(e) = std::fs::write(&tmp, &bytes).and_then(|_| std::fs::rename(&tmp, path)) {
        // Whether the write or the rename failed, leave no orphan temp file.
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("write artifact {path:?} (via {tmp:?})"));
    }
    Ok(bytes)
}

/// Read and decode an artifact file under the [`LoadMode::from_env`]
/// default mode (`IAOI_LOAD`, else copy).
pub fn read_file(path: &Path) -> Result<ModelArtifact> {
    read_file_with(path, LoadMode::from_env())
}

/// Read and decode an artifact file with an explicit weight-storage
/// strategy: copy every tensor, borrow from a shared heap buffer, or
/// borrow from an `mmap` of the file.
pub fn read_file_with(path: &Path, mode: LoadMode) -> Result<ModelArtifact> {
    let buf = match mode {
        LoadMode::Copy => {
            let bytes = std::fs::read(path).with_context(|| format!("read artifact {path:?}"))?;
            return load(&bytes).with_context(|| format!("decode artifact {path:?}"));
        }
        LoadMode::ZeroCopy => ArtifactBytes::read_file(path)
            .with_context(|| format!("read artifact {path:?}"))?,
        LoadMode::Mmap => ArtifactBytes::map_file(path)
            .with_context(|| format!("map artifact {path:?}"))?,
    };
    load_shared(&buf).with_context(|| format!("decode artifact {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::graph::builders::papernet_random;
    use crate::quantize::{quantize_graph, QuantMode, QuantizeOptions};
    use crate::tensor::Tensor;

    fn demo_artifact_mode(seed: u64, mode: QuantMode) -> ModelArtifact {
        let g = papernet_random(8, FusedActivation::Relu6, seed);
        let mut rng = Rng::seeded(seed);
        let calib: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, 16, 16, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
        ModelArtifact::new("demo", 3, [16, 16, 3], q)
    }

    fn demo_artifact(seed: u64) -> ModelArtifact {
        demo_artifact_mode(seed, QuantMode::PerTensor)
    }

    #[test]
    fn save_load_save_is_byte_identical() {
        // Deterministic encoding + lossless decoding ⇒ a second round trip
        // reproduces the bytes exactly.
        let art = demo_artifact(11);
        let bytes = save(&art).expect("encode");
        let loaded = load(&bytes).expect("load");
        assert_eq!(loaded.name, "demo");
        assert_eq!(loaded.version, 3);
        assert_eq!(loaded.input_shape, [16, 16, 3]);
        assert_eq!(loaded.graph.nodes.len(), art.graph.nodes.len());
        assert_eq!(save(&loaded).expect("re-encode"), bytes);
    }

    #[test]
    fn zero_copy_load_is_bit_identical_and_borrows() {
        let art = demo_artifact(15);
        let bytes = save(&art).expect("encode");
        let buf = crate::tensor::ArtifactBytes::from_vec(bytes.clone());
        let shared = load_shared(&buf).expect("load_shared");
        assert!(shared.backing.is_some());
        // Weight storage borrows the buffer; everything decodes equal.
        let mut views = 0;
        for node in &shared.graph.nodes {
            match &node.op {
                QOp::Conv(c) => views += usize::from(c.weights.is_view()),
                QOp::Depthwise(d) => views += usize::from(d.weights.is_view()),
                QOp::Fc(fc) => views += usize::from(fc.weights.is_view()),
                _ => {}
            }
        }
        assert!(views > 0, "large weight tensors must borrow the artifact buffer");
        assert_eq!(save(&shared).expect("re-encode"), bytes, "views re-encode losslessly");
    }

    #[test]
    fn header_errors_are_structured() {
        let art = demo_artifact(12);
        let bytes = save(&art).expect("encode");

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(load(&bad), Err(DecodeError::BadMagic { .. })));

        let mut future = bytes.clone();
        future[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(
            load(&future).unwrap_err(),
            DecodeError::UnsupportedVersion { found: 99, supported: FORMAT_VERSION }
        );

        // Junk after a complete artifact lands in the checksummed span, so
        // the checksum reports it first …
        let mut trailing = bytes.clone();
        trailing.extend_from_slice(&[1, 2, 3]);
        assert!(matches!(load(&trailing).unwrap_err(), DecodeError::ChecksumMismatch { .. }));
        // … and once the checksum is consistent again, the structural
        // trailing-bytes diagnostic still fires.
        restamp_checksum(&mut trailing);
        assert_eq!(load(&trailing).unwrap_err(), DecodeError::TrailingBytes { extra: 3 });

        assert!(matches!(load(&bytes[..5]), Err(DecodeError::Truncated { .. })));
    }

    #[test]
    fn checksum_catches_any_payload_flip() {
        let art = demo_artifact(14);
        let bytes = save(&art).expect("encode");
        for pos in (PAYLOAD_OFFSET..bytes.len()).step_by(11) {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x01;
            assert!(
                matches!(load(&corrupt), Err(DecodeError::ChecksumMismatch { .. })),
                "flip at {pos} slipped past the checksum"
            );
        }
        // A flipped checksum byte itself also fails verification.
        let mut corrupt = bytes.clone();
        corrupt[8] ^= 0x01;
        assert!(matches!(load(&corrupt), Err(DecodeError::ChecksumMismatch { .. })));
    }

    #[test]
    fn multiplier_integrity_check_fires() {
        let art = demo_artifact(13);
        let mut bytes = save(&art).expect("encode");
        // The final node is the FC classifier; its multiplier is the last
        // 8 bytes of the file. Corrupt the mantissa, then restamp the
        // header checksum so the deeper integrity check is reachable.
        let n = bytes.len();
        bytes[n - 8] ^= 0x40;
        restamp_checksum(&mut bytes);
        match load(&bytes) {
            Err(DecodeError::MultiplierMismatch { .. }) => {}
            other => panic!("expected MultiplierMismatch, got {other:?}"),
        }
    }

    #[test]
    fn degenerate_scales_are_encode_errors_not_panics() {
        let mut art = demo_artifact(16);
        let mut fc_node = None;
        for (idx, node) in art.graph.nodes.iter_mut().enumerate() {
            if let QOp::Fc(fc) = &mut node.op {
                fc.output_params.scale = 0.0; // multiplier becomes infinite
                fc_node = Some(idx);
            }
        }
        let fc_node = fc_node.expect("demo net ends in an FC classifier");
        assert_eq!(save(&art).unwrap_err(), EncodeError::NonFiniteMultiplier { node: fc_node });

        let mut art = demo_artifact(16);
        for node in art.graph.nodes.iter_mut() {
            if let QOp::Fc(fc) = &mut node.op {
                fc.input_params.scale = f64::NAN;
            }
        }
        assert!(matches!(save(&art).unwrap_err(), EncodeError::NonFiniteMultiplier { .. }));

        // Oversized variable-length fields are structured errors too.
        let mut art = demo_artifact(16);
        art.name = "n".repeat(usize::from(u16::MAX) + 1);
        assert!(matches!(save(&art).unwrap_err(), EncodeError::TooLarge { what: "string", .. }));

        let mut art = demo_artifact(16);
        art.input_shape = [16, 0, 3];
        assert_eq!(
            save(&art).unwrap_err(),
            EncodeError::InvalidArtifact { what: "zero input shape dimension" }
        );
    }

    #[test]
    fn per_channel_artifact_roundtrips_and_checks_integrity() {
        let art = demo_artifact_mode(29, QuantMode::PerChannel);
        let bytes = save(&art).expect("encode");
        let loaded = load(&bytes).expect("load per-channel artifact");
        // Per-channel weight quantization survives the round trip exactly.
        let mut saw_per_channel = false;
        for (a, b) in art.graph.nodes.iter().zip(&loaded.graph.nodes) {
            match (&a.op, &b.op) {
                (QOp::Conv(x), QOp::Conv(y)) => {
                    assert_eq!(x.weight_quant, y.weight_quant, "{}", a.name);
                    saw_per_channel |= x.weight_quant.is_per_channel();
                }
                (QOp::Depthwise(x), QOp::Depthwise(y)) => {
                    assert_eq!(x.weight_quant, y.weight_quant, "{}", a.name);
                    saw_per_channel |= x.weight_quant.is_per_channel();
                }
                _ => {}
            }
        }
        assert!(saw_per_channel, "converter should have produced per-channel nodes");
        assert_eq!(save(&loaded).expect("re-encode"), bytes, "deterministic re-encode");

        // Corrupting one per-channel multiplier fires the integrity check.
        // Flip every few bytes, restamp the checksum so the flip survives
        // header verification, require no panic, and that at least one
        // flip lands in a multiplier and yields MultiplierMismatch.
        let mut saw_mismatch = false;
        for pos in (PAYLOAD_OFFSET..bytes.len()).step_by(3) {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x20;
            restamp_checksum(&mut corrupt);
            if let Err(DecodeError::MultiplierMismatch { .. }) = load(&corrupt) {
                saw_mismatch = true;
            }
        }
        assert!(saw_mismatch, "flipping multiplier bytes must be detected");
    }

    #[test]
    fn display_messages_are_informative() {
        let e = DecodeError::Truncated { offset: 12, needed: 4 };
        assert!(e.to_string().contains("offset 12"));
        let e = DecodeError::UnsupportedVersion { found: 9, supported: 1 };
        assert!(e.to_string().contains('9'));
        let e = DecodeError::BadCount {
            offset: 3,
            what: "f64 slice",
            count: u64::from(u32::MAX),
            width: 8,
            remaining: 10,
        };
        let s = e.to_string();
        assert!(s.contains("4294967295") && s.contains("34359738360"), "{s}");
        let e = DecodeError::ChecksumMismatch { stored: 1, computed: 2 };
        assert!(e.to_string().contains("checksum"));
        let e = EncodeError::NonFiniteMultiplier { node: 4 };
        assert!(e.to_string().contains("node 4"));
    }

    #[test]
    fn load_mode_labels_roundtrip() {
        for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
            assert_eq!(LoadMode::from_label(mode.label()), Some(mode));
        }
        assert_eq!(LoadMode::from_label("bogus"), None);
    }
}
