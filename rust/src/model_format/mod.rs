//! The `.iaoiq` quantized-model artifact format: a self-describing binary
//! serialization of a complete integer-only [`QGraph`] — the repo's
//! counterpart of the TFLite flatbuffer the paper deploys through gemmlowp.
//! A model is quantized once (PTQ or QAT export), written to disk, and from
//! then on every serving process loads the artifact directly; reloading is
//! lossless, so inference from a loaded graph is **bit-identical** to the
//! in-memory original.
//!
//! ## Layout (version 2, all little-endian)
//!
//! ```text
//! magic        b"IAOQ"                                    4 bytes
//! version      u32                                        currently 2
//! name         u16 len + utf-8                            registry model name
//! model_ver    u32                                        registry version
//! input_shape  u32 × 3                                    H, W, C of one example
//! kernel       u8                                         GEMM kernel code
//! input_qp     QuantParams wire                           20 bytes (f64 scale,
//!                                                         i32 zp/qmin/qmax)
//! node_count   u32
//! node × count:
//!   name       u16 len + utf-8
//!   input      u32                                        0xFFFF_FFFF = graph
//!                                                         input, else node idx
//!   op_code    u8                                         see table below
//!   payload    op-specific (see `encode_op`)
//! ```
//!
//! Op codes: 0 conv2d, 1 depthwise, 2 fully-connected, 3 avg-pool,
//! 4 max-pool, 5 global-avg-pool, 6 add, 7 concat, 8 softmax, 9 logistic.
//! Conv-like payloads carry the uint8 weight tensor, the weight
//! quantization, the int32 bias vector (eq. 11), stride/padding, the
//! fused-activation code, and the normalized requantization multiplier(s)
//! `2^shift · M0` (eq. 5–6). The multipliers are redundant with the stored
//! scales; the loader recomputes and rejects the file on mismatch, so
//! bit-rot in any of the fields is caught at load time.
//!
//! **Version 2** (append-only): the conv-like weight-quantization field
//! starts with a mode byte — 0 = per-tensor followed by the classic
//! 20-byte [`QuantParams`], 1 = per-channel followed by `zero_point`,
//! `qmin`, `qmax` (i32 each) and a count-prefixed f64 scale vector
//! (one scale per output channel, Krishnamoorthi 1806.08342) — and the
//! trailing multiplier block carries one `(m0, shift)` pair per channel.
//! Version 1 artifacts (no mode byte, always per-tensor, single
//! multiplier) still decode bit-identically; `rust/tests/model_format.rs`
//! pins a golden v1 blob.
//!
//! Decoding is fully bounds-checked ([`wire::Reader`]) and never panics or
//! over-allocates on corrupt input; every failure is a structured
//! [`DecodeError`].

pub mod wire;

use crate::gemm::Kernel;
use crate::graph::{NodeRef, QGraph, QNode, QOp};
use crate::nn::conv::QConv2d;
use crate::nn::depthwise::QDepthwiseConv2d;
use crate::nn::fc::QFullyConnected;
use crate::nn::{FusedActivation, Padding};
use crate::quant::{ChannelQuantParams, QuantParams, QuantizedMultiplier, WeightQuant};
use anyhow::{Context, Result};
use std::fmt;
use std::path::Path;
use wire::{Reader, Writer};

/// File magic.
pub const MAGIC: &[u8; 4] = b"IAOQ";
/// Current format version (v2 = per-channel weight scales; v1 artifacts
/// still load).
pub const FORMAT_VERSION: u32 = 2;
/// Canonical file extension (without the dot).
pub const EXTENSION: &str = "iaoiq";

const INPUT_REF: u32 = u32::MAX;

/// Weight-quantization mode byte (v2+, append-only).
const WQ_PER_TENSOR: u8 = 0;
const WQ_PER_CHANNEL: u8 = 1;

const OP_CONV: u8 = 0;
const OP_DEPTHWISE: u8 = 1;
const OP_FC: u8 = 2;
const OP_AVG_POOL: u8 = 3;
const OP_MAX_POOL: u8 = 4;
const OP_GLOBAL_AVG_POOL: u8 = 5;
const OP_ADD: u8 = 6;
const OP_CONCAT: u8 = 7;
const OP_SOFTMAX: u8 = 8;
const OP_LOGISTIC: u8 = 9;

/// Structured decode failure: what went wrong and where.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The buffer ended before a field: `needed` more bytes at `offset`.
    Truncated { offset: usize, needed: usize },
    /// First four bytes are not [`MAGIC`].
    BadMagic { found: [u8; 4] },
    /// Format version newer than this build understands.
    UnsupportedVersion { found: u32, supported: u32 },
    /// A length-prefixed string is not UTF-8.
    BadUtf8 { offset: usize },
    /// Unknown op code on a node.
    BadOpCode { node: usize, code: u8 },
    /// An enum field (padding, activation, kernel, rank) holds an unknown
    /// code.
    BadEnum { what: &'static str, value: u8 },
    /// A node references the graph input sentinel incorrectly or a node
    /// that is not strictly earlier in the DAG.
    BadNodeRef { node: usize, reference: u32 },
    /// A header field fails semantic validation (empty model name, zero
    /// input dimension, bad graph-input quant params).
    InvalidHeader { what: &'static str },
    /// A node field decoded but fails semantic validation (shape arity,
    /// bias length, non-positive scale, zero stride, …).
    InvalidField { node: usize, what: &'static str },
    /// Nodes decoded individually but the graph fails whole-topology
    /// validation; carries the validator's description.
    InvalidGraph { detail: String },
    /// The stored requantization multiplier does not match the one derived
    /// from the stored scales (eq. 5) — the file is corrupt.
    MultiplierMismatch { node: usize },
    /// Well-formed artifact followed by junk bytes.
    TrailingBytes { extra: usize },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Truncated { offset, needed } => {
                write!(f, "truncated artifact: needed {needed} more bytes at offset {offset}")
            }
            DecodeError::BadMagic { found } => {
                write!(f, "bad magic {found:?} (expected {MAGIC:?}) — not an .iaoiq artifact")
            }
            DecodeError::UnsupportedVersion { found, supported } => {
                write!(f, "artifact format version {found} is newer than supported version {supported}")
            }
            DecodeError::BadUtf8 { offset } => write!(f, "non-UTF-8 name at offset {offset}"),
            DecodeError::BadOpCode { node, code } => {
                write!(f, "node {node}: unknown op code {code}")
            }
            DecodeError::BadEnum { what, value } => write!(f, "unknown {what} code {value}"),
            DecodeError::BadNodeRef { node, reference } => {
                write!(f, "node {node}: reference {reference} is not an earlier node")
            }
            DecodeError::InvalidHeader { what } => write!(f, "invalid artifact header: {what}"),
            DecodeError::InvalidField { node, what } => write!(f, "node {node}: invalid {what}"),
            DecodeError::InvalidGraph { detail } => write!(f, "invalid graph: {detail}"),
            DecodeError::MultiplierMismatch { node } => {
                write!(f, "node {node}: stored requantization multiplier disagrees with stored scales")
            }
            DecodeError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after a complete artifact")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// A serialized-model unit: the quantized graph plus the registry metadata
/// ([`crate::coordinator::registry`]) that names and versions it.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    /// Registry name (non-empty).
    pub name: String,
    /// Monotonic model version — bumped on each retrain/hot-swap.
    pub version: u32,
    /// Shape `[H, W, C]` of one input example (batch dim excluded).
    pub input_shape: [usize; 3],
    /// The integer-only graph.
    pub graph: QGraph,
}

impl ModelArtifact {
    pub fn new(
        name: impl Into<String>,
        version: u32,
        input_shape: [usize; 3],
        graph: QGraph,
    ) -> Self {
        let name = name.into();
        assert!(!name.is_empty(), "artifact name must be non-empty");
        Self { name, version, input_shape, graph }
    }

    /// The batched NHWC input shape for a batch of `n`.
    pub fn batched_shape(&self, n: usize) -> [usize; 4] {
        [n, self.input_shape[0], self.input_shape[1], self.input_shape[2]]
    }

    /// Build the prepared execution plan for serving this artifact —
    /// load → prepare is the deployment path ([`crate::coordinator::registry`]
    /// does this at install/swap time): weights are packed and output stages
    /// built once here, never per request. Prepared inference is
    /// bit-identical to running [`Self::graph`] directly.
    pub fn prepare(&self) -> crate::graph::PreparedGraph {
        self.graph.prepare()
    }
}

/// The eq. 5 requantization multiplier(s) of a conv-like node, normalized
/// for integer application: one per output channel in per-channel mode,
/// one total otherwise. `None` when a scale combination is degenerate
/// (possible only in corrupt files; valid converters always produce
/// positive finite scales).
fn requant_multipliers(
    weight: &WeightQuant,
    input: &QuantParams,
    output: &QuantParams,
) -> Option<Vec<QuantizedMultiplier>> {
    let rows = weight.channels().unwrap_or(1);
    (0..rows)
        .map(|ch| {
            let m = weight.scale(ch) * input.scale / output.scale;
            if m.is_finite() && m > 0.0 {
                Some(QuantizedMultiplier::from_f64(m))
            } else {
                None
            }
        })
        .collect()
}

/// Encode a conv-like node's weight quantization (v2 layout: mode byte then
/// the mode-specific parameter block).
fn encode_weight_quant(w: &mut Writer, wq: &WeightQuant) {
    match wq {
        WeightQuant::PerTensor(p) => {
            w.put_u8(WQ_PER_TENSOR);
            w.put_quant_params(p);
        }
        WeightQuant::PerChannel(c) => {
            w.put_u8(WQ_PER_CHANNEL);
            w.put_i32(c.zero_point);
            w.put_i32(c.qmin);
            w.put_i32(c.qmax);
            w.put_f64_slice(&c.scales);
        }
    }
}

/// Decode a conv-like node's weight quantization. Version 1 files carry a
/// bare per-tensor [`QuantParams`] with no mode byte.
fn decode_weight_quant(
    r: &mut Reader,
    node: usize,
    version: u32,
) -> Result<WeightQuant, DecodeError> {
    if version < 2 {
        return Ok(WeightQuant::PerTensor(decode_quant_params(r, node, "weight quant params")?));
    }
    let mode = r.u8()?;
    match mode {
        WQ_PER_TENSOR => {
            Ok(WeightQuant::PerTensor(decode_quant_params(r, node, "weight quant params")?))
        }
        WQ_PER_CHANNEL => {
            let zero_point = r.i32()?;
            let qmin = r.i32()?;
            let qmax = r.i32()?;
            let scales = r.f64_slice()?;
            let c = ChannelQuantParams { scales, zero_point, qmin, qmax };
            if c.wire_valid() {
                Ok(WeightQuant::PerChannel(c))
            } else {
                Err(DecodeError::InvalidField { node, what: "per-channel weight quant params" })
            }
        }
        other => Err(DecodeError::BadEnum { what: "weight quant mode", value: other }),
    }
}

/// Encode the trailing multiplier block: one `(m0, shift)` pair per output
/// channel (a single pair in per-tensor mode).
fn encode_multipliers(w: &mut Writer, wq: &WeightQuant, input: &QuantParams, output: &QuantParams) {
    let ms = requant_multipliers(wq, input, output)
        .expect("valid graph has finite requant multipliers");
    for m in ms {
        w.put_i32(m.m0);
        w.put_i32(m.shift);
    }
}

fn encode_ref(w: &mut Writer, r: NodeRef) {
    match r {
        NodeRef::Input => w.put_u32(INPUT_REF),
        NodeRef::Node(i) => {
            assert!((i as u64) < u64::from(INPUT_REF), "node index overflows wire format");
            w.put_u32(i as u32);
        }
    }
}

fn decode_ref(raw: u32, node: usize) -> Result<NodeRef, DecodeError> {
    if raw == INPUT_REF {
        return Ok(NodeRef::Input);
    }
    if (raw as usize) < node {
        Ok(NodeRef::Node(raw as usize))
    } else {
        Err(DecodeError::BadNodeRef { node, reference: raw })
    }
}

fn decode_quant_params(
    r: &mut Reader,
    node: usize,
    what: &'static str,
) -> Result<QuantParams, DecodeError> {
    let p = r.quant_params()?;
    if p.wire_valid() {
        Ok(p)
    } else {
        Err(DecodeError::InvalidField { node, what })
    }
}

fn encode_op(w: &mut Writer, op: &QOp) {
    match op {
        QOp::Conv(c) => {
            w.put_u8(OP_CONV);
            w.put_u8_tensor(&c.weights);
            encode_weight_quant(w, &c.weight_quant);
            w.put_i32_slice(&c.bias);
            w.put_u32(c.stride as u32);
            w.put_u8(c.padding.code());
            w.put_quant_params(&c.input_params);
            w.put_quant_params(&c.output_params);
            w.put_u8(c.activation.code());
            encode_multipliers(w, &c.weight_quant, &c.input_params, &c.output_params);
        }
        QOp::Depthwise(d) => {
            w.put_u8(OP_DEPTHWISE);
            w.put_u8_tensor(&d.weights);
            encode_weight_quant(w, &d.weight_quant);
            w.put_i32_slice(&d.bias);
            w.put_u32(d.stride as u32);
            w.put_u8(d.padding.code());
            w.put_quant_params(&d.input_params);
            w.put_quant_params(&d.output_params);
            w.put_u8(d.activation.code());
            encode_multipliers(w, &d.weight_quant, &d.input_params, &d.output_params);
        }
        QOp::Fc(fc) => {
            w.put_u8(OP_FC);
            w.put_u8_tensor(&fc.weights);
            encode_weight_quant(w, &fc.weight_quant);
            w.put_i32_slice(&fc.bias);
            w.put_quant_params(&fc.input_params);
            w.put_quant_params(&fc.output_params);
            w.put_u8(fc.activation.code());
            encode_multipliers(w, &fc.weight_quant, &fc.input_params, &fc.output_params);
        }
        QOp::AvgPool { kernel, stride, padding } => {
            w.put_u8(OP_AVG_POOL);
            w.put_u32(*kernel as u32);
            w.put_u32(*stride as u32);
            w.put_u8(padding.code());
        }
        QOp::MaxPool { kernel, stride, padding } => {
            w.put_u8(OP_MAX_POOL);
            w.put_u32(*kernel as u32);
            w.put_u32(*stride as u32);
            w.put_u8(padding.code());
        }
        QOp::GlobalAvgPool => w.put_u8(OP_GLOBAL_AVG_POOL),
        QOp::Add { other, out_params } => {
            w.put_u8(OP_ADD);
            encode_ref(w, *other);
            w.put_quant_params(out_params);
        }
        QOp::Concat { others, out_params } => {
            w.put_u8(OP_CONCAT);
            assert!(others.len() <= u32::MAX as usize);
            w.put_u32(others.len() as u32);
            for r in others {
                encode_ref(w, *r);
            }
            w.put_quant_params(out_params);
        }
        QOp::Softmax => w.put_u8(OP_SOFTMAX),
        QOp::Logistic => w.put_u8(OP_LOGISTIC),
    }
}

/// Decode the conv-like common tail: stride, padding, the activation-side
/// parameter sets, activation, and the integrity-checked multiplier block
/// (one `(m0, shift)` pair per output channel).
struct ConvTail {
    stride: usize,
    padding: Padding,
    input_params: QuantParams,
    output_params: QuantParams,
    activation: FusedActivation,
}

fn decode_conv_tail(
    r: &mut Reader,
    node: usize,
    weight_quant: &WeightQuant,
    with_geometry: bool,
) -> Result<ConvTail, DecodeError> {
    let (stride, padding) = if with_geometry {
        let stride = r.u32()? as usize;
        if stride == 0 {
            return Err(DecodeError::InvalidField { node, what: "stride" });
        }
        let pad_code = r.u8()?;
        let padding = Padding::from_code(pad_code)
            .ok_or(DecodeError::BadEnum { what: "padding", value: pad_code })?;
        (stride, padding)
    } else {
        (1, Padding::Same)
    };
    let input_params = decode_quant_params(r, node, "input quant params")?;
    let output_params = decode_quant_params(r, node, "output quant params")?;
    let act_code = r.u8()?;
    let activation = FusedActivation::from_code(act_code)
        .ok_or(DecodeError::BadEnum { what: "activation", value: act_code })?;
    let derived = requant_multipliers(weight_quant, &input_params, &output_params)
        .ok_or(DecodeError::InvalidField { node, what: "requant multiplier" })?;
    for d in derived {
        let stored = QuantizedMultiplier { m0: r.i32()?, shift: r.i32()? };
        if stored != d {
            return Err(DecodeError::MultiplierMismatch { node });
        }
    }
    Ok(ConvTail { stride, padding, input_params, output_params, activation })
}

/// Per-channel scale vectors must be one-per-output-channel; `channels` is
/// the op's channel dimension from the decoded weight tensor.
fn check_weight_channels(
    wq: &WeightQuant,
    channels: usize,
    node: usize,
) -> Result<(), DecodeError> {
    match wq.channels() {
        Some(c) if c != channels => {
            Err(DecodeError::InvalidField { node, what: "per-channel scale count" })
        }
        _ => Ok(()),
    }
}

fn decode_op(r: &mut Reader, node: usize, version: u32) -> Result<QOp, DecodeError> {
    let code = r.u8()?;
    match code {
        OP_CONV => {
            let weights = r.u8_tensor()?;
            if weights.rank() != 4 {
                return Err(DecodeError::InvalidField { node, what: "conv weight rank" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(0), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(0) {
                return Err(DecodeError::InvalidField { node, what: "conv bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, true)?;
            Ok(QOp::Conv(QConv2d {
                weights,
                weight_quant,
                bias,
                stride: tail.stride,
                padding: tail.padding,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_DEPTHWISE => {
            let weights = r.u8_tensor()?;
            if weights.rank() != 4 || weights.dim(0) != 1 {
                return Err(DecodeError::InvalidField { node, what: "depthwise weight shape" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(3), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(3) {
                return Err(DecodeError::InvalidField { node, what: "depthwise bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, true)?;
            Ok(QOp::Depthwise(QDepthwiseConv2d {
                weights,
                weight_quant,
                bias,
                stride: tail.stride,
                padding: tail.padding,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_FC => {
            let weights = r.u8_tensor()?;
            if weights.rank() != 2 {
                return Err(DecodeError::InvalidField { node, what: "fc weight rank" });
            }
            let weight_quant = decode_weight_quant(r, node, version)?;
            check_weight_channels(&weight_quant, weights.dim(0), node)?;
            let bias = r.i32_slice()?;
            if !bias.is_empty() && bias.len() != weights.dim(0) {
                return Err(DecodeError::InvalidField { node, what: "fc bias length" });
            }
            let tail = decode_conv_tail(r, node, &weight_quant, false)?;
            Ok(QOp::Fc(QFullyConnected {
                weights,
                weight_quant,
                bias,
                input_params: tail.input_params,
                output_params: tail.output_params,
                activation: tail.activation,
            }))
        }
        OP_AVG_POOL | OP_MAX_POOL => {
            let kernel = r.u32()? as usize;
            let stride = r.u32()? as usize;
            if kernel == 0 || stride == 0 {
                return Err(DecodeError::InvalidField { node, what: "pool geometry" });
            }
            let pad_code = r.u8()?;
            let padding = Padding::from_code(pad_code)
                .ok_or(DecodeError::BadEnum { what: "padding", value: pad_code })?;
            Ok(if code == OP_AVG_POOL {
                QOp::AvgPool { kernel, stride, padding }
            } else {
                QOp::MaxPool { kernel, stride, padding }
            })
        }
        OP_GLOBAL_AVG_POOL => Ok(QOp::GlobalAvgPool),
        OP_ADD => {
            let other = decode_ref(r.u32()?, node)?;
            let out_params = decode_quant_params(r, node, "add output quant params")?;
            Ok(QOp::Add { other, out_params })
        }
        OP_CONCAT => {
            let count = r.u32()? as usize;
            // Each ref is 4 bytes; bound before allocating.
            if count.saturating_mul(4) > r.remaining_bytes() {
                return Err(DecodeError::Truncated {
                    offset: r.offset(),
                    needed: count.saturating_mul(4),
                });
            }
            let mut others = Vec::with_capacity(count);
            for _ in 0..count {
                others.push(decode_ref(r.u32()?, node)?);
            }
            let out_params = decode_quant_params(r, node, "concat output quant params")?;
            Ok(QOp::Concat { others, out_params })
        }
        OP_SOFTMAX => Ok(QOp::Softmax),
        OP_LOGISTIC => Ok(QOp::Logistic),
        other => Err(DecodeError::BadOpCode { node, code: other }),
    }
}

/// Serialize an artifact to bytes. Total order of fields is documented in
/// the module header; the encoding is deterministic, so equal graphs yield
/// byte-equal artifacts (used by tests as a losslessness oracle).
pub fn save(artifact: &ModelArtifact) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_bytes(MAGIC);
    w.put_u32(FORMAT_VERSION);
    w.put_str(&artifact.name);
    w.put_u32(artifact.version);
    for &d in &artifact.input_shape {
        assert!(d >= 1 && d <= u32::MAX as usize, "input shape dims must be positive");
        w.put_u32(d as u32);
    }
    w.put_u8(artifact.graph.kernel.code());
    w.put_quant_params(&artifact.graph.input_params);
    assert!(artifact.graph.nodes.len() <= u32::MAX as usize);
    w.put_u32(artifact.graph.nodes.len() as u32);
    for node in &artifact.graph.nodes {
        w.put_str(&node.name);
        encode_ref(&mut w, node.input);
        encode_op(&mut w, &node.op);
    }
    w.into_bytes()
}

/// Deserialize an artifact, validating structure, enums, DAG ordering, and
/// the per-layer multiplier integrity check. Never panics on corrupt input.
pub fn load(bytes: &[u8]) -> Result<ModelArtifact, DecodeError> {
    let mut r = Reader::new(bytes);
    let magic: [u8; 4] = r.take(4)?.try_into().unwrap();
    if &magic != MAGIC {
        return Err(DecodeError::BadMagic { found: magic });
    }
    let version = r.u32()?;
    if version > FORMAT_VERSION {
        return Err(DecodeError::UnsupportedVersion { found: version, supported: FORMAT_VERSION });
    }
    let name = r.str()?;
    if name.is_empty() {
        return Err(DecodeError::InvalidHeader { what: "empty model name" });
    }
    let model_version = r.u32()?;
    let mut input_shape = [0usize; 3];
    for d in &mut input_shape {
        *d = r.u32()? as usize;
        if *d == 0 {
            return Err(DecodeError::InvalidHeader { what: "zero input shape dimension" });
        }
    }
    let kernel_code = r.u8()?;
    let kernel = Kernel::from_code(kernel_code)
        .ok_or(DecodeError::BadEnum { what: "gemm kernel", value: kernel_code })?;
    let input_params = r.quant_params()?;
    if !input_params.wire_valid() {
        return Err(DecodeError::InvalidHeader { what: "graph input quant params" });
    }
    let node_count = r.u32()? as usize;
    let mut nodes: Vec<QNode> = Vec::new();
    for idx in 0..node_count {
        let node_name = r.str()?;
        let input = decode_ref(r.u32()?, idx)?;
        let op = decode_op(&mut r, idx, version)?;
        nodes.push(QNode { name: node_name, input, op });
    }
    r.finish()?;
    let graph = QGraph { input_params, nodes, kernel };
    // Belt-and-braces: decode_ref already enforces backward references, but
    // run the graph-level validator so the invariant has a single source of
    // truth shared with other producers.
    if let Err(detail) = graph.validate_topology() {
        return Err(DecodeError::InvalidGraph { detail });
    }
    Ok(ModelArtifact { name, version: model_version, input_shape, graph })
}

/// Write an artifact file (conventionally `<anything>.iaoiq`).
pub fn write_file(path: &Path, artifact: &ModelArtifact) -> Result<()> {
    std::fs::write(path, save(artifact)).with_context(|| format!("write artifact {path:?}"))?;
    Ok(())
}

/// Read and decode an artifact file.
pub fn read_file(path: &Path) -> Result<ModelArtifact> {
    let bytes = std::fs::read(path).with_context(|| format!("read artifact {path:?}"))?;
    let artifact = load(&bytes).with_context(|| format!("decode artifact {path:?}"))?;
    Ok(artifact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::graph::builders::papernet_random;
    use crate::quantize::{quantize_graph, QuantMode, QuantizeOptions};
    use crate::tensor::Tensor;

    fn demo_artifact_mode(seed: u64, mode: QuantMode) -> ModelArtifact {
        let g = papernet_random(8, FusedActivation::Relu6, seed);
        let mut rng = Rng::seeded(seed);
        let calib: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, 16, 16, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
        ModelArtifact::new("demo", 3, [16, 16, 3], q)
    }

    fn demo_artifact(seed: u64) -> ModelArtifact {
        demo_artifact_mode(seed, QuantMode::PerTensor)
    }

    #[test]
    fn save_load_save_is_byte_identical() {
        // Deterministic encoding + lossless decoding ⇒ a second round trip
        // reproduces the bytes exactly.
        let art = demo_artifact(11);
        let bytes = save(&art);
        let loaded = load(&bytes).expect("load");
        assert_eq!(loaded.name, "demo");
        assert_eq!(loaded.version, 3);
        assert_eq!(loaded.input_shape, [16, 16, 3]);
        assert_eq!(loaded.graph.nodes.len(), art.graph.nodes.len());
        assert_eq!(save(&loaded), bytes);
    }

    #[test]
    fn header_errors_are_structured() {
        let art = demo_artifact(12);
        let bytes = save(&art);

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(load(&bad), Err(DecodeError::BadMagic { .. })));

        let mut future = bytes.clone();
        future[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(
            load(&future).unwrap_err(),
            DecodeError::UnsupportedVersion { found: 99, supported: FORMAT_VERSION }
        );

        let mut trailing = bytes.clone();
        trailing.extend_from_slice(&[1, 2, 3]);
        assert_eq!(load(&trailing).unwrap_err(), DecodeError::TrailingBytes { extra: 3 });

        assert!(matches!(load(&bytes[..5]), Err(DecodeError::Truncated { .. })));
    }

    #[test]
    fn multiplier_integrity_check_fires() {
        let art = demo_artifact(13);
        let mut bytes = save(&art);
        // The final node is the FC classifier; its multiplier is the last
        // 8 bytes of the file. Corrupt the mantissa.
        let n = bytes.len();
        bytes[n - 8] ^= 0x40;
        match load(&bytes) {
            Err(DecodeError::MultiplierMismatch { .. }) => {}
            other => panic!("expected MultiplierMismatch, got {other:?}"),
        }
    }

    #[test]
    fn per_channel_artifact_roundtrips_and_checks_integrity() {
        let art = demo_artifact_mode(29, QuantMode::PerChannel);
        let bytes = save(&art);
        let loaded = load(&bytes).expect("load per-channel artifact");
        // Per-channel weight quantization survives the round trip exactly.
        let mut saw_per_channel = false;
        for (a, b) in art.graph.nodes.iter().zip(&loaded.graph.nodes) {
            match (&a.op, &b.op) {
                (QOp::Conv(x), QOp::Conv(y)) => {
                    assert_eq!(x.weight_quant, y.weight_quant, "{}", a.name);
                    saw_per_channel |= x.weight_quant.is_per_channel();
                }
                (QOp::Depthwise(x), QOp::Depthwise(y)) => {
                    assert_eq!(x.weight_quant, y.weight_quant, "{}", a.name);
                    saw_per_channel |= x.weight_quant.is_per_channel();
                }
                _ => {}
            }
        }
        assert!(saw_per_channel, "converter should have produced per-channel nodes");
        assert_eq!(save(&loaded), bytes, "deterministic re-encode");

        // Corrupting one per-channel multiplier fires the integrity check.
        // The first conv node's multiplier block sits right after its
        // activation byte; flip a mantissa byte by scanning for the first
        // difference a corrupted scale would produce — simplest robust
        // probe: flip every byte and require no panic, and that at least
        // one flip yields MultiplierMismatch.
        let mut saw_mismatch = false;
        for pos in (0..bytes.len()).step_by(3) {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x20;
            if let Err(DecodeError::MultiplierMismatch { .. }) = load(&corrupt) {
                saw_mismatch = true;
            }
        }
        assert!(saw_mismatch, "flipping multiplier bytes must be detected");
    }

    #[test]
    fn display_messages_are_informative() {
        let e = DecodeError::Truncated { offset: 12, needed: 4 };
        assert!(e.to_string().contains("offset 12"));
        let e = DecodeError::UnsupportedVersion { found: 9, supported: 1 };
        assert!(e.to_string().contains('9'));
    }
}
