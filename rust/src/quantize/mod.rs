//! Conversion of a float graph into the integer-only inference graph —
//! the Rust counterpart of the TFLite converter the paper describes
//! (Algorithm 1 steps 4–5).
//!
//! Pipeline:
//! 1. **Fold batch norms** (eq. 14, §3.2) so weights are quantized post-fold.
//! 2. **Calibrate** activation ranges by running the float graph over
//!    representative batches, aggregating per-node min/max with the EMA of
//!    §3.1 (for QAT-trained models the L2 side exports its learned ranges
//!    instead — same [`Calibration`] shape).
//! 3. **Convert**: per-layer weight quantization (min/max with the
//!    narrow-range nudge, or symmetric per-channel scales under
//!    [`QuantMode::PerChannel`]), eq. 11 bias quantization, eq. 5
//!    multiplier per layer (per output channel in per-channel mode),
//!    activation-clamp fusion (ReLU/ReLU6 collapse into the producer's
//!    clamp), and the App. A.3 concat-parameter unification.

use crate::gemm::Kernel;
use crate::graph::{FloatGraph, FloatOp, NodeRef, QGraph, QNode, QOp};
use crate::nn::conv::QConv2d;
use crate::nn::depthwise::QDepthwiseConv2d;
use crate::nn::fc::QFullyConnected;
use crate::nn::FusedActivation;
use crate::quant::{ChannelAxis, ChannelQuantParams, EmaRange, QuantParams, WeightQuant};
use crate::tensor::Tensor;

/// Observed activation statistics for a folded float graph: one range per
/// node output plus the graph input.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub input: EmaRange,
    pub ranges: Vec<EmaRange>,
}

/// Run the folded float graph over calibration batches collecting EMA
/// ranges (§3.1: smoothing parameter close to 1 across many steps; for the
/// handful of PTQ batches used here a lower decay converges faster).
pub fn calibrate<'a>(
    graph: &FloatGraph,
    batches: impl Iterator<Item = &'a Tensor<f32>>,
    decay: f64,
) -> Calibration {
    let mut input = EmaRange::new(decay);
    let mut ranges = vec![EmaRange::new(decay); graph.nodes.len()];
    let mut saw_any = false;
    for batch in batches {
        saw_any = true;
        input.observe(batch.data());
        let outs = graph.run_all(batch);
        for (r, t) in ranges.iter_mut().zip(&outs) {
            r.observe(t.data());
        }
    }
    assert!(saw_any, "calibration requires at least one batch");
    Calibration { input, ranges }
}

/// Weight-quantization granularity the converter applies to conv,
/// depthwise and fully-connected layers (FC quantizes per output unit —
/// a row of its `[out, in]` weight matrix — which matters on wide
/// classifier heads with heterogeneous per-unit weight magnitudes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum QuantMode {
    /// One `(S, Z)` pair per weight array — the paper's scheme.
    #[default]
    PerTensor,
    /// Symmetric per-output-channel weight scales
    /// (Krishnamoorthi 1806.08342): recovers accuracy on layers whose
    /// channels carry very different ranges, above all BN-folded depthwise.
    PerChannel,
}

impl QuantMode {
    /// Stable label used by bench artifacts and CLI flags.
    pub fn label(self) -> &'static str {
        match self {
            QuantMode::PerTensor => "per_tensor",
            QuantMode::PerChannel => "per_channel",
        }
    }

    /// Inverse of [`Self::label`], accepting `-`/`_` spellings.
    pub fn from_label(s: &str) -> Option<Self> {
        match s.replace('-', "_").as_str() {
            "per_tensor" => Some(QuantMode::PerTensor),
            "per_channel" => Some(QuantMode::PerChannel),
            _ => None,
        }
    }
}

/// Conversion knobs (bit depths drive the Tables 4.7/4.8 ablations).
#[derive(Clone, Copy, Debug)]
pub struct QuantizeOptions {
    pub weight_bits: u32,
    pub activation_bits: u32,
    pub kernel: Kernel,
    /// Weight granularity for conv/depthwise layers.
    pub mode: QuantMode,
}

impl Default for QuantizeOptions {
    fn default() -> Self {
        Self {
            weight_bits: 8,
            activation_bits: 8,
            kernel: Kernel::default(),
            mode: QuantMode::default(),
        }
    }
}

/// Quantize one weight array (+ bias) for a matmul-shaped layer under the
/// chosen mode: returns the uint8 weights, the [`WeightQuant`] carrier, and
/// the eq. 11 int32 bias.
fn quantize_weights(
    w: &Tensor<f32>,
    bias: &[f32],
    channels: usize,
    axis: ChannelAxis,
    in_params: &QuantParams,
    bits: u32,
    mode: QuantMode,
) -> (Tensor<u8>, WeightQuant, Vec<i32>) {
    match mode {
        QuantMode::PerTensor => {
            let wp = QuantParams::for_weights(w.data(), bits);
            let bp = QuantParams::for_bias(&wp, in_params);
            (w.map(|v| wp.quantize(v) as u8), WeightQuant::PerTensor(wp), bp.quantize_bias_slice(bias))
        }
        QuantMode::PerChannel => {
            let cq = ChannelQuantParams::for_weights(w.data(), channels, axis, bits);
            let data = cq.quantize_slice(w.data(), axis);
            let qbias = cq.quantize_bias(bias, in_params.scale);
            (Tensor::from_vec(w.shape(), data), WeightQuant::PerChannel(cq), qbias)
        }
    }
}

/// Convert a (possibly BN-bearing) float graph into the integer-only graph.
///
/// `calibration` must have been collected on `graph.fold_batch_norms()` —
/// call [`quantize_graph`] to do both steps at once.
pub fn convert(folded: &FloatGraph, calibration: &Calibration, opts: QuantizeOptions) -> QGraph {
    assert_eq!(calibration.ranges.len(), folded.nodes.len(), "calibration/graph mismatch");
    let (aq_min, aq_max) = QuantParams::range_for_bits(opts.activation_bits, false);
    let params_of = |r: &EmaRange| r.params(aq_min, aq_max);

    // ---- Pass 1: decide each node's output QuantParams, with ReLU fusion
    // and concat unification.
    let n = folded.nodes.len();
    // fused_into[i] = Some(j): node i (a standalone ReLU/ReLU6) is absorbed
    // by producer j; consumers of i must read j.
    let mut fused_into: Vec<Option<usize>> = vec![None; n];
    // The activation a producer must clamp with, if a ReLU was absorbed.
    let mut absorbed_act: Vec<FusedActivation> = vec![FusedActivation::None; n];
    let mut out_params: Vec<QuantParams> = calibration.ranges.iter().map(&params_of).collect();

    for i in 0..n {
        match &folded.nodes[i].op {
            FloatOp::Relu | FloatOp::Relu6 => {
                if let NodeRef::Node(p) = folded.nodes[i].input {
                    if matches!(
                        folded.nodes[p].op,
                        FloatOp::Conv(_) | FloatOp::Depthwise(_) | FloatOp::Fc(_) | FloatOp::Add(_)
                    ) {
                        let root = fused_into[p].unwrap_or(p);
                        fused_into[i] = Some(root);
                        absorbed_act[root] = match folded.nodes[i].op {
                            FloatOp::Relu => FusedActivation::Relu,
                            _ => FusedActivation::Relu6,
                        };
                        // The producer's effective output range is the
                        // post-activation range.
                        out_params[root] = out_params[i];
                    }
                }
            }
            FloatOp::BatchNorm(_) => panic!("convert() requires a folded graph (call fold_batch_norms first)"),
            _ => {}
        }
    }
    // Concat unification (App. A.3): all inputs share the concat's params.
    let resolve = |r: NodeRef, fused: &Vec<Option<usize>>| -> NodeRef {
        match r {
            NodeRef::Node(i) => NodeRef::Node(fused[i].unwrap_or(i)),
            x => x,
        }
    };
    for i in 0..n {
        if let FloatOp::Concat(others) = &folded.nodes[i].op {
            let unified = out_params[fused_into[i].unwrap_or(i)];
            let mut operands = vec![folded.nodes[i].input];
            operands.extend(others.iter().copied());
            for r in operands {
                if let NodeRef::Node(p) = resolve(r, &fused_into) {
                    out_params[p] = unified;
                }
            }
        }
        // Pools keep their producer's params exactly (TFLite semantics).
        if matches!(
            folded.nodes[i].op,
            FloatOp::AvgPool { .. } | FloatOp::MaxPool { .. } | FloatOp::GlobalAvgPool
        ) {
            if let NodeRef::Node(p) = resolve(folded.nodes[i].input, &fused_into) {
                out_params[i] = out_params[p];
            }
        }
    }

    let input_params = calibration.input.params(aq_min, aq_max);
    let params_at = |r: NodeRef, out_params: &Vec<QuantParams>| -> QuantParams {
        match resolve(r, &fused_into) {
            NodeRef::Input => input_params,
            NodeRef::Node(i) => out_params[i],
        }
    };

    // ---- Pass 2: build the quantized graph, skipping fused nodes.
    let mut qnodes: Vec<QNode> = Vec::new();
    let mut remap: Vec<Option<usize>> = vec![None; n]; // folded idx -> q idx
    let map_ref = |r: NodeRef, remap: &Vec<Option<usize>>| -> NodeRef {
        match resolve(r, &fused_into) {
            NodeRef::Input => NodeRef::Input,
            NodeRef::Node(i) => NodeRef::Node(remap[i].expect("forward reference")),
        }
    };

    for i in 0..n {
        if fused_into[i].is_some() {
            // Absorbed ReLU: consumers are redirected to the producer.
            continue;
        }
        let node = &folded.nodes[i];
        let in_params = params_at(node.input, &out_params);
        let op = match &node.op {
            FloatOp::Conv(c) => {
                let act = combine_act(c.activation, absorbed_act[i]);
                let (weights, weight_quant, bias) = quantize_weights(
                    &c.weights,
                    &c.bias,
                    c.weights.dim(0),
                    ChannelAxis::Outer,
                    &in_params,
                    opts.weight_bits,
                    opts.mode,
                );
                QOp::Conv(QConv2d {
                    weights,
                    weight_quant,
                    bias,
                    stride: c.stride,
                    padding: c.padding,
                    input_params: in_params,
                    output_params: out_params[i],
                    activation: act,
                })
            }
            FloatOp::Depthwise(d) => {
                let act = combine_act(d.activation, absorbed_act[i]);
                let (weights, weight_quant, bias) = quantize_weights(
                    &d.weights,
                    &d.bias,
                    d.weights.dim(3),
                    ChannelAxis::Inner,
                    &in_params,
                    opts.weight_bits,
                    opts.mode,
                );
                QOp::Depthwise(QDepthwiseConv2d {
                    weights,
                    weight_quant,
                    bias,
                    stride: d.stride,
                    padding: d.padding,
                    input_params: in_params,
                    output_params: out_params[i],
                    activation: act,
                })
            }
            FloatOp::Fc(f) => {
                let act = combine_act(f.activation, absorbed_act[i]);
                // Per-channel FC quantizes per output unit (row of the
                // `[out, in]` weight matrix) — the win shows on wide
                // classifier heads whose units carry very different weight
                // magnitudes (see `bench --table quant-modes`).
                let (weights, weight_quant, bias) = quantize_weights(
                    &f.weights,
                    &f.bias,
                    f.weights.dim(0),
                    ChannelAxis::Outer,
                    &in_params,
                    opts.weight_bits,
                    opts.mode,
                );
                QOp::Fc(QFullyConnected {
                    weights,
                    weight_quant,
                    bias,
                    input_params: in_params,
                    output_params: out_params[i],
                    activation: act,
                })
            }
            FloatOp::AvgPool { kernel, stride, padding } => {
                QOp::AvgPool { kernel: *kernel, stride: *stride, padding: *padding }
            }
            FloatOp::MaxPool { kernel, stride, padding } => {
                QOp::MaxPool { kernel: *kernel, stride: *stride, padding: *padding }
            }
            FloatOp::GlobalAvgPool => QOp::GlobalAvgPool,
            FloatOp::Add(other) => QOp::Add {
                other: map_ref(*other, &remap),
                out_params: out_params[i],
            },
            FloatOp::Concat(others) => QOp::Concat {
                others: others.iter().map(|r| map_ref(*r, &remap)).collect(),
                out_params: out_params[i],
            },
            FloatOp::Softmax => QOp::Softmax,
            FloatOp::Logistic => QOp::Logistic,
            FloatOp::Relu | FloatOp::Relu6 => {
                // Unfusable standalone activation (e.g. after a pool):
                // represent as an Add-with-zero clamp would be wasteful;
                // instead clamp via the node's own params on a no-op concat.
                // In practice the builders never produce this.
                panic!("standalone activation after {:?} is not supported; fuse it", node.input)
            }
            FloatOp::BatchNorm(_) => unreachable!("folded above"),
        };
        qnodes.push(QNode { name: node.name.clone(), input: map_ref(node.input, &remap), op });
        remap[i] = Some(qnodes.len() - 1);
    }

    QGraph { input_params, nodes: qnodes, kernel: opts.kernel }
}

fn combine_act(a: FusedActivation, b: FusedActivation) -> FusedActivation {
    match (a, b) {
        (FusedActivation::None, x) => x,
        (x, FusedActivation::None) => x,
        (x, y) => {
            assert_eq!(x, y, "conflicting fused activations");
            x
        }
    }
}

/// The full PTQ pipeline: fold BN, calibrate over `batches`, convert.
pub fn quantize_graph(
    graph: &FloatGraph,
    batches: &[Tensor<f32>],
    opts: QuantizeOptions,
) -> (FloatGraph, QGraph) {
    let folded = graph.fold_batch_norms();
    let calib = calibrate(&folded, batches.iter(), 0.7);
    let q = convert(&folded, &calib, opts);
    (folded, q)
}

/// Weight-only baseline quantization (Table 4.2): replace each weight array
/// by its scheme-quantized-then-dequantized version; the model still runs
/// on the float engine (these schemes keep float activations).
pub fn apply_weight_scheme(graph: &FloatGraph, scheme: crate::quant::schemes::WeightScheme) -> FloatGraph {
    let mut out = graph.clone();
    for node in &mut out.nodes {
        match &mut node.op {
            FloatOp::Conv(c) => {
                let stride = c.weights.len() / c.weights.dim(0);
                let q = scheme.apply(c.weights.data(), stride);
                c.weights = Tensor::from_vec(c.weights.shape(), q);
            }
            FloatOp::Depthwise(d) => {
                let q = scheme.apply(d.weights.data(), d.weights.len());
                d.weights = Tensor::from_vec(d.weights.shape(), q);
            }
            FloatOp::Fc(f) => {
                let q = scheme.apply(f.weights.data(), f.weights.dim(1));
                f.weights = Tensor::from_vec(f.weights.shape(), q);
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::graph::builders;

    fn calib_batches(rng: &mut Rng, shape: &[usize], count: usize) -> Vec<Tensor<f32>> {
        (0..count)
            .map(|_| {
                let mut d = vec![0f32; shape.iter().product()];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(shape, d)
            })
            .collect()
    }

    #[test]
    fn papernet_ptq_tracks_float() {
        let mut rng = Rng::seeded(7);
        let g = builders::papernet_random(16, FusedActivation::Relu6, 7);
        let batches = calib_batches(&mut rng, &[2, 16, 16, 3], 4);
        let (folded, q) = quantize_graph(&g, &batches, QuantizeOptions::default());

        // On fresh data, the quantized logits must track the float logits.
        let x = calib_batches(&mut rng, &[4, 16, 16, 3], 1).pop().unwrap();
        let want = folded.run(&x);
        let got = q.run(&x);
        let diff = want.max_abs_diff(&got);
        // Logit-level agreement within a small absolute budget.
        assert!(diff < 0.25, "PTQ logits diff {diff}");
        // And argmax agreement on most rows.
        let classes = want.dim(1);
        let mut agree = 0;
        for b in 0..4 {
            let am = |t: &Tensor<f32>| {
                (0..classes)
                    .max_by(|&i, &j| {
                        t.data()[b * classes + i].partial_cmp(&t.data()[b * classes + j]).unwrap()
                    })
                    .unwrap()
            };
            if am(&want) == am(&got) {
                agree += 1;
            }
        }
        assert!(agree >= 3, "argmax agreement {agree}/4");
    }

    #[test]
    fn resnet_ptq_handles_bypass_and_relu_fusion() {
        let mut rng = Rng::seeded(17);
        let g = builders::mini_resnet(1, 8, 17);
        let batches = calib_batches(&mut rng, &[2, 12, 12, 3], 3);
        let (folded, q) = quantize_graph(&g, &batches, QuantizeOptions::default());
        // Standalone ReLUs must all be fused away.
        assert!(q.nodes.len() < folded.nodes.len());
        let x = &batches[0];
        let want = folded.run(x);
        let got = q.run(x);
        assert_eq!(want.shape(), got.shape());
        let diff = want.max_abs_diff(&got);
        assert!(diff < 0.6, "resnet PTQ diff {diff}");
    }

    #[test]
    fn per_channel_ptq_tracks_float() {
        let mut rng = Rng::seeded(47);
        let g = builders::papernet_random(16, FusedActivation::Relu6, 47);
        let batches = calib_batches(&mut rng, &[2, 16, 16, 3], 4);
        let opts = QuantizeOptions { mode: QuantMode::PerChannel, ..Default::default() };
        let (folded, q) = quantize_graph(&g, &batches, opts);
        // Conv/depthwise quantize per channel; FC per output unit.
        for node in &q.nodes {
            match &node.op {
                QOp::Conv(c) => assert!(c.weight_quant.is_per_channel(), "{}", node.name),
                QOp::Depthwise(d) => assert!(d.weight_quant.is_per_channel(), "{}", node.name),
                QOp::Fc(f) => assert!(f.weight_quant.is_per_channel(), "{}", node.name),
                _ => {}
            }
        }
        let x = calib_batches(&mut rng, &[4, 16, 16, 3], 1).pop().unwrap();
        // Symmetric per-channel can be locally ~2x coarser than affine on a
        // skewed channel, so the budget is slightly looser than the
        // per-tensor test's; heterogeneous-channel wins are asserted in
        // per_channel_beats_per_tensor_on_heterogeneous_depthwise.
        let diff = folded.run(&x).max_abs_diff(&q.run(&x));
        assert!(diff < 0.35, "per-channel PTQ logits diff {diff}");
    }

    #[test]
    fn per_channel_beats_per_tensor_on_heterogeneous_depthwise() {
        let mut rng = Rng::seeded(53);
        let g = builders::papernet_heterogeneous_dw(16, 53);
        let batches = calib_batches(&mut rng, &[2, 16, 16, 3], 4);
        let (folded, q_pt) = quantize_graph(&g, &batches, QuantizeOptions::default());
        let (_, q_pc) = quantize_graph(
            &g,
            &batches,
            QuantizeOptions { mode: QuantMode::PerChannel, ..Default::default() },
        );
        let x = calib_batches(&mut rng, &[8, 16, 16, 3], 1).pop().unwrap();
        let want = folded.run(&x);
        let mean_err = |got: &Tensor<f32>| -> f64 {
            want.data()
                .iter()
                .zip(got.data())
                .map(|(a, b)| f64::from((a - b).abs()))
                .sum::<f64>()
                / want.len() as f64
        };
        let pt_err = mean_err(&q_pt.run(&x));
        let pc_err = mean_err(&q_pc.run(&x));
        assert!(
            pc_err < pt_err,
            "per-channel logit error ({pc_err}) must beat per-tensor ({pt_err})"
        );
    }

    #[test]
    fn quant_mode_labels_roundtrip() {
        for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
            assert_eq!(QuantMode::from_label(mode.label()), Some(mode));
        }
        assert_eq!(QuantMode::from_label("per-channel"), Some(QuantMode::PerChannel));
        assert_eq!(QuantMode::from_label("nope"), None);
    }

    #[test]
    fn quantized_model_is_4x_smaller() {
        let g = builders::papernet_random(16, FusedActivation::Relu6, 3);
        let folded = g.fold_batch_norms();
        let mut rng = Rng::seeded(3);
        let batches = calib_batches(&mut rng, &[1, 16, 16, 3], 2);
        let calib = calibrate(&folded, batches.iter(), 0.7);
        let q = convert(&folded, &calib, QuantizeOptions::default());
        let fbytes = folded.model_bytes();
        let qbytes = q.model_bytes();
        // The paper's headline 4x size reduction (biases stay 32-bit so the
        // ratio is slightly under 4).
        // PaperNet is tiny so 32-bit biases are a visible fraction; the
        // ratio approaches 4.0 as weight volume dominates (MobileNet-scale).
        let ratio = fbytes as f64 / qbytes as f64;
        assert!(ratio > 3.0 && ratio <= 4.0, "size ratio {ratio} ({fbytes}B -> {qbytes}B)");
    }

    #[test]
    fn bit_depth_option_degrades_gracefully() {
        // 4-bit weights must still run and be *worse* than 8-bit (Table 4.7
        // trend), checked on reconstruction error of the logits.
        let mut rng = Rng::seeded(23);
        let g = builders::papernet_random(8, FusedActivation::Relu6, 23);
        let batches = calib_batches(&mut rng, &[2, 16, 16, 3], 3);
        let (folded, q8) = quantize_graph(&g, &batches, QuantizeOptions::default());
        let (_, q4) = quantize_graph(
            &g,
            &batches,
            QuantizeOptions { weight_bits: 4, ..Default::default() },
        );
        let x = &batches[0];
        let want = folded.run(x);
        let d8 = want.max_abs_diff(&q8.run(x));
        let d4 = want.max_abs_diff(&q4.run(x));
        assert!(d4 > d8, "4-bit ({d4}) should be worse than 8-bit ({d8})");
    }

    #[test]
    fn weight_scheme_baselines_run_on_float_engine() {
        use crate::quant::schemes::WeightScheme;
        let g = builders::papernet_random(8, FusedActivation::Relu6, 29);
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        let want_shape = g.run(&x);
        for scheme in [WeightScheme::Binary, WeightScheme::Ternary, WeightScheme::PowerOfTwo { bits: 5 }] {
            let gq = apply_weight_scheme(&g, scheme);
            let y = gq.run(&x);
            assert_eq!(y.shape(), want_shape.shape(), "{scheme:?}");
        }
    }

    #[test]
    fn calibration_is_deterministic() {
        let g = builders::papernet_random(8, FusedActivation::Relu6, 31).fold_batch_norms();
        let mut rng = Rng::seeded(31);
        let batches = calib_batches(&mut rng, &[1, 16, 16, 3], 2);
        let c1 = calibrate(&g, batches.iter(), 0.9);
        let c2 = calibrate(&g, batches.iter(), 0.9);
        for (a, b) in c1.ranges.iter().zip(&c2.ranges) {
            assert_eq!((a.min, a.max), (b.min, b.max));
        }
    }
}
