"""L2: the QAT model family ("PaperNet") — JAX forward/backward with
simulated quantization (section 3), batch-norm folding (section 3.2,
figs. C.7/C.8), EMA activation ranges (section 3.1) and delayed activation
quantization.

The architecture family is config-driven (depth blocks, width multiplier,
input resolution) so the Rust harness can reproduce the paper's sweeps
(Table 4.1 depths, the MobileNet DM x resolution figures) from a handful of
AOT artifacts. Quantization *knobs* are traced scalars — weight-quant
on/off, activation ceiling (ReLU vs ReLU6), weight/activation bit depths —
so a single compiled train step covers float baselines, Table 4.3's
nonlinearity comparison and Tables 4.7/4.8's bit-depth grid.

Folding during training follows fig. C.7: the convolution is evaluated once
with raw weights to obtain batch statistics, the weights are folded with
those statistics, fake-quantized, and applied in a second convolution —
"quantize weights after they have been scaled by the batch normalization
parameters". Export folds with the EMA statistics (eq. 14, fig. C.6) and
transposes to the Rust engine's OHWI layout.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile import quant
from compile.kernels import fake_quant as fq_kernel

BN_EPS = 1e-3
BN_DECAY = 0.9
RANGE_DECAY = 0.99
LEARNING_RATE = 0.03
MOMENTUM = 0.9  # the paper's ResNet protocol (App. D.1) uses momentum 0.9
ACT_QUANT_DELAY = 100  # steps; section 3.1's delayed activation quantization
RELU6_CEIL = 6.0
RELU_CEIL = 1e9  # "ReLU": effectively uncapped


@dataclasses.dataclass(frozen=True)
class Config:
    """One member of the PaperNet family."""

    depth_blocks: int = 1  # extra (dw s1 + pw) pairs at the middle stage
    width_mult: float = 1.0
    resolution: int = 16
    channels: int = 3
    num_classes: int = 16
    batch: int = 32

    def width(self, base: int) -> int:
        return max(4, int(round(base * self.width_mult / 4.0)) * 4)

    def layers(self):
        """[(name, kind, stride, cin, cout)] with kind in {conv, dw}."""
        w8, w16, w32 = self.width(8), self.width(16), self.width(32)
        layers = [("conv0", "conv", 1, self.channels, w8)]
        layers += [("dw1", "dw", 2, w8, w8), ("pw1", "conv", 1, w8, w16)]
        for i in range(self.depth_blocks - 1):
            layers += [
                (f"mdw{i}", "dw", 1, w16, w16),
                (f"mpw{i}", "conv", 1, w16, w16),
            ]
        layers += [("dw2", "dw", 2, w16, w16), ("pw2", "conv", 1, w16, w32)]
        return layers

    @property
    def fc_in(self) -> int:
        return self.width(32)

    @property
    def conv_layer_count(self) -> int:
        return len(self.layers()) + 1  # + fc, the paper's depth counting

    def param_keys(self):
        return [f"{n}/{p}" for (n, _, _, _, _) in self.layers() for p in ("w", "gamma", "beta")] + [
            "fc/w",
            "fc/b",
        ]

    def bn_keys(self):
        return [f"{n}/{p}" for (n, _, _, _, _) in self.layers() for p in ("mean", "var")]

    def range_keys(self):
        return [f"{n}/act" for (n, _, _, _, _) in self.layers()] + ["logits/act"]

    def export_keys(self):
        return [f"{n}/{p}" for (n, _, _, _, _) in self.layers() for p in ("w", "b")] + [
            "fc/w",
            "fc/b",
        ]


DEFAULT = Config()

# Module-level views of the default config (used by tests and the quickstart
# artifact; variant-specific values live in each artifact's spec file).
LAYERS = DEFAULT.layers()
FC_IN = DEFAULT.fc_in
RESOLUTION = DEFAULT.resolution
CHANNELS = DEFAULT.channels
NUM_CLASSES = DEFAULT.num_classes
BATCH = DEFAULT.batch
PARAM_KEYS = DEFAULT.param_keys()
BN_KEYS = DEFAULT.bn_keys()
RANGE_KEYS = DEFAULT.range_keys()
EXPORT_KEYS = DEFAULT.export_keys()


def param_shapes(config: Config = DEFAULT) -> dict[str, tuple[int, ...]]:
    shapes: dict[str, tuple[int, ...]] = {}
    for name, kind, _, cin, cout in config.layers():
        if kind == "conv":
            k = 3 if name == "conv0" else 1  # stem is 3x3, pointwise are 1x1
            shapes[f"{name}/w"] = (k, k, cin, cout)  # HWIO
        else:
            shapes[f"{name}/w"] = (3, 3, 1, cout)  # depthwise HWIO (groups=C)
        shapes[f"{name}/gamma"] = (cout,)
        shapes[f"{name}/beta"] = (cout,)
    shapes["fc/w"] = (config.fc_in, config.num_classes)
    shapes["fc/b"] = (config.num_classes,)
    return shapes


def init_params(seed: int = 0, config: Config = DEFAULT) -> dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params: dict[str, jnp.ndarray] = {}
    for name, shape in param_shapes(config).items():
        key, sub = jax.random.split(key)
        if name.endswith("/w"):
            fan_in = int(jnp.prod(jnp.array(shape[:-1])))
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
        elif name.endswith("gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


def init_bn_state(config: Config = DEFAULT) -> dict[str, jnp.ndarray]:
    state: dict[str, jnp.ndarray] = {}
    for name, _, _, _, cout in config.layers():
        state[f"{name}/mean"] = jnp.zeros((cout,), jnp.float32)
        state[f"{name}/var"] = jnp.ones((cout,), jnp.float32)
    return state


def init_ranges(config: Config = DEFAULT) -> dict[str, jnp.ndarray]:
    # Start at the ReLU6 natural range; EMAs take over from the first step.
    return {k: jnp.array([0.0, 6.0], jnp.float32) for k in config.range_keys()}


def init_momenta(params) -> dict[str, jnp.ndarray]:
    return {k: jnp.zeros_like(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _conv(x, w, stride: int, depthwise: bool):
    if depthwise:
        groups = w.shape[-1]
        return jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups,
        )
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _fq(x, rmin, rmax, qmin, qmax, use_pallas: bool):
    if use_pallas:
        return fq_kernel.fake_quant_ste(x, rmin, rmax, qmin, qmax)
    return _ref_ste(x, rmin, rmax, qmin, qmax)


def _fq_weights(w, w_qmax, use_pallas: bool):
    # Narrow range [1, qmax]: int8 never takes -128 (section 3.1, App. B).
    rmin = jnp.min(jax.lax.stop_gradient(w))
    rmax = jnp.max(jax.lax.stop_gradient(w))
    return _fq(w, rmin, rmax, jnp.float32(1.0), w_qmax, use_pallas)


@jax.custom_vjp
def _ref_ste(x, rmin, rmax, qmin, qmax):
    return quant.fake_quant_reference(x, rmin, rmax, qmin, qmax)


def _ref_ste_fwd(x, rmin, rmax, qmin, qmax):
    return quant.fake_quant_reference(x, rmin, rmax, qmin, qmax), (x, rmin, rmax, qmin, qmax)


def _ref_ste_bwd(res, g):
    x, rmin, rmax, qmin, qmax = res
    scale, zp = quant.nudged_params(rmin, rmax, qmin, qmax)
    lo = scale * (qmin - zp)
    hi = scale * (qmax - zp)
    mask = jnp.logical_and(x >= lo, x <= hi).astype(g.dtype)
    zero = jnp.zeros_like(rmin)
    return (g * mask, zero, zero, jnp.zeros_like(qmin), jnp.zeros_like(qmax))


_ref_ste.defvjp(_ref_ste_fwd, _ref_ste_bwd)


def forward(
    params,
    bn_state,
    ranges,
    x,
    *,
    training: bool,
    quantize: bool,
    act_quant_on,
    w_quant_on=1.0,
    act_ceiling=RELU6_CEIL,
    w_qmax=255.0,
    a_qmax=255.0,
    use_pallas: bool = False,
    config: Config = DEFAULT,
):
    """PaperNet forward.

    Returns (logits, new_bn_state, new_ranges). In eval modes the returned
    states equal the inputs. `act_quant_on`, `w_quant_on`, `act_ceiling`,
    `w_qmax`, `a_qmax` are traced scalars so one compiled step covers the
    delayed-activation schedule, float baselines, ReLU-vs-ReLU6 and the
    bit-depth grid.
    """
    act_quant_on = jnp.float32(act_quant_on)
    w_quant_on = jnp.float32(w_quant_on)
    act_ceiling = jnp.float32(act_ceiling)
    w_qmax = jnp.float32(w_qmax)
    a_qmax = jnp.float32(a_qmax)
    a_qmin = jnp.float32(0.0)

    new_bn = dict(bn_state)
    new_ranges = dict(ranges)
    h = x
    for name, kind, stride, _cin, _cout in config.layers():
        w = params[f"{name}/w"]
        gamma = params[f"{name}/gamma"]
        beta = params[f"{name}/beta"]
        depthwise = kind == "dw"
        if training:
            # fig. C.7: first conv with raw weights for batch statistics.
            y_raw = _conv(h, w, stride, depthwise)
            axes = (0, 1, 2)
            mu = jnp.mean(y_raw, axis=axes)
            var = jnp.var(y_raw, axis=axes)
            new_bn[f"{name}/mean"] = BN_DECAY * bn_state[f"{name}/mean"] + (1 - BN_DECAY) * mu
            new_bn[f"{name}/var"] = BN_DECAY * bn_state[f"{name}/var"] + (1 - BN_DECAY) * var
        else:
            mu = bn_state[f"{name}/mean"]
            var = bn_state[f"{name}/var"]
        scales = gamma / jnp.sqrt(var + BN_EPS)  # eq. 14
        b_fold = beta - scales * mu
        w_fold = w * scales  # broadcast over the HWIO output-channel axis
        if quantize:
            wq = _fq_weights(w_fold, w_qmax, use_pallas)
            w_fold = w_quant_on * wq + (1.0 - w_quant_on) * w_fold
        y = _conv(h, w_fold, stride, depthwise) + b_fold
        y = jnp.clip(y, 0.0, act_ceiling)
        if quantize:
            rng_key = f"{name}/act"
            if training:
                bmin = jnp.min(jax.lax.stop_gradient(y))
                bmax = jnp.max(jax.lax.stop_gradient(y))
                nmin, nmax = quant.ema_update(
                    ranges[rng_key][0], ranges[rng_key][1], bmin, bmax, RANGE_DECAY
                )
                new_ranges[rng_key] = jnp.stack([nmin, nmax])
            pair = new_ranges[rng_key] if training else ranges[rng_key]
            yq = _fq(y, pair[0], pair[1], a_qmin, a_qmax, use_pallas)
            y = act_quant_on * yq + (1.0 - act_quant_on) * y
        h = y
    # Global average pool + FC head.
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["fc/w"] + params["fc/b"]
    if quantize:
        key = "logits/act"
        if training:
            bmin = jnp.min(jax.lax.stop_gradient(logits))
            bmax = jnp.max(jax.lax.stop_gradient(logits))
            nmin, nmax = quant.ema_update(
                ranges[key][0], ranges[key][1], bmin, bmax, RANGE_DECAY
            )
            new_ranges[key] = jnp.stack([nmin, nmax])
        pair = new_ranges[key] if training else ranges[key]
        lq = _fq(logits, pair[0], pair[1], a_qmin, a_qmax, False)
        logits = act_quant_on * lq + (1.0 - act_quant_on) * logits
    return logits, new_bn, new_ranges


def cross_entropy(logits, labels, num_classes: int):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Train step (SGD with momentum, App. D.1 protocol scaled down).
# ---------------------------------------------------------------------------


def train_step(
    params,
    momenta,
    bn_state,
    ranges,
    x,
    labels,
    act_quant_on,
    w_quant_on=1.0,
    act_ceiling=RELU6_CEIL,
    w_qmax=255.0,
    a_qmax=255.0,
    *,
    use_pallas: bool = False,
    config: Config = DEFAULT,
):
    """One QAT SGD-momentum step. Functional: returns all new state.

    With `w_quant_on = act_quant_on = 0` the same compiled step trains the
    float baseline (BN statistics still flow through the folded graph)."""

    def loss_fn(p):
        logits, new_bn, new_ranges = forward(
            p,
            bn_state,
            ranges,
            x,
            training=True,
            quantize=True,
            act_quant_on=act_quant_on,
            w_quant_on=w_quant_on,
            act_ceiling=act_ceiling,
            w_qmax=w_qmax,
            a_qmax=a_qmax,
            use_pallas=use_pallas,
            config=config,
        )
        return cross_entropy(logits, labels, config.num_classes), (new_bn, new_ranges)

    (loss, (new_bn, new_ranges)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    new_params = {}
    new_momenta = {}
    for k in params:
        v = MOMENTUM * momenta[k] + grads[k]
        new_momenta[k] = v
        new_params[k] = params[k] - LEARNING_RATE * v
    return new_params, new_momenta, new_bn, new_ranges, loss


def eval_logits(
    params,
    bn_state,
    ranges,
    x,
    *,
    quantize: bool,
    act_ceiling=RELU6_CEIL,
    w_qmax=255.0,
    a_qmax=255.0,
    use_pallas: bool = False,
    config: Config = DEFAULT,
):
    """Eval forward: float (`quantize=False`) or quant-sim (`True`)."""
    logits, _, _ = forward(
        params,
        bn_state,
        ranges,
        x,
        training=False,
        quantize=quantize,
        act_quant_on=jnp.float32(1.0),
        w_quant_on=jnp.float32(1.0),
        act_ceiling=act_ceiling,
        w_qmax=w_qmax,
        a_qmax=a_qmax,
        use_pallas=use_pallas,
        config=config,
    )
    return logits


# ---------------------------------------------------------------------------
# Export: folded inference parameters (eq. 14) in the Rust OHWI layout.
# ---------------------------------------------------------------------------


def export_folded(params, bn_state, config: Config = DEFAULT):
    """Fold BN into weights/biases with the EMA statistics (fig. C.6) and
    transpose into the layouts `rust/src/graph/builders.rs::papernet`
    expects: conv OHWI `[cout, kh, kw, cin]`, depthwise `[1, kh, kw, c]`,
    fc `[units, in]`."""
    out: dict[str, jnp.ndarray] = {}
    for name, kind, _stride, _cin, _cout in config.layers():
        w = params[f"{name}/w"]
        scales = params[f"{name}/gamma"] / jnp.sqrt(bn_state[f"{name}/var"] + BN_EPS)
        b_fold = params[f"{name}/beta"] - scales * bn_state[f"{name}/mean"]
        w_fold = w * scales
        if kind == "conv":
            out[f"{name}/w"] = jnp.transpose(w_fold, (3, 0, 1, 2))  # HWIO -> OHWI
        else:
            out[f"{name}/w"] = jnp.transpose(w_fold, (2, 0, 1, 3))  # HWI(C) -> 1HWC
        out[f"{name}/b"] = b_fold
    out["fc/w"] = jnp.transpose(params["fc/w"], (1, 0))  # [in,out] -> [out,in]
    out["fc/b"] = params["fc/b"]
    return out
