"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Two oracles:

* ``fake_quant_ref`` — eq. 12 simulated quantization, shared with
  ``compile.quant.fake_quant_reference``.
* ``qmatmul_ref`` — the full integer-arithmetic-only matmul of sections
  2.2-2.4: uint8 operands, int32 accumulation via the eq. 7 zero-point
  decomposition, int32 bias, fixed-point requantization (eq. 6 multiplier,
  SQRDMULH + correctly-rounding shift), saturating cast and clamp. This is
  the bit-exact reference the Rust `gemm` module must also match.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import quant


def fake_quant_ref(x, rmin, rmax, qmin: float, qmax: float):
    """Eq. 12 oracle (delegates to the shared jnp implementation)."""
    return quant.fake_quant_reference(x, rmin, rmax, qmin, qmax)


def qmatmul_ref(
    q1,  # uint8 [M, K]  (weights)
    q2,  # uint8 [K, N]  (activations)
    z1: int,
    z2: int,
    bias,  # int32 [M] or None
    m0: int,
    right_shift: int,
    z3: int,
    clamp_min: int = 0,
    clamp_max: int = 255,
):
    """Integer-only quantized matmul, eq. 7 + the section 2.4 pipeline.

    Everything is integer arithmetic: the only real-number input, the
    multiplier M = S1*S2/S3, has already been normalized offline into
    (m0, right_shift) per eq. 6.
    """
    k = q1.shape[1]
    a1 = q1.astype(jnp.int32)
    a2 = q2.astype(jnp.int32)
    raw = jnp.matmul(a1, a2)  # eq. 9: the O(N^3) core on raw uint8 codes
    row_sums = jnp.sum(a1, axis=1, keepdims=True)  # a-bar_1 (eq. 8)
    col_sums = jnp.sum(a2, axis=0, keepdims=True)  # a_2 (eq. 8)
    acc = raw + k * z1 * z2 - z1 * col_sums - z2 * row_sums  # eq. 7
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[:, None]  # eq. 11 bias
    scaled = quant.apply_multiplier(acc, m0, right_shift)
    q = scaled + jnp.int32(z3)
    q = jnp.clip(q, 0, 255)  # saturating cast to uint8
    q = jnp.clip(q, clamp_min, clamp_max)  # fused activation clamp
    return q.astype(jnp.uint8)


def qmatmul_float_view(q1, q2, s1, s2, z1, z2, bias_real, s3, z3):
    """What the quantized matmul *means* in real numbers: dequantize inputs,
    real matmul, quantize output. Used to bound the integer pipeline's error
    in tests (they must agree to within one output LSB)."""
    r1 = s1 * (q1.astype(jnp.float32) - z1)
    r2 = s2 * (q2.astype(jnp.float32) - z2)
    r3 = jnp.matmul(r1, r2)
    if bias_real is not None:
        r3 = r3 + bias_real[:, None]
    q = jnp.clip(jnp.round(r3 / s3) + z3, 0, 255)
    return q.astype(jnp.uint8)
