"""L1 Pallas kernel: integer-arithmetic-only matrix multiplication.

The compute hot-spot of quantized inference (eq. 7 + the section 2.4 fused
pipeline) expressed as a Pallas kernel:

* uint8 operands, int32 accumulator (eq. 10),
* zero-point handling via the eq. 7 row/column-sum decomposition — the
  O(N^2) corrections are computed inside the tile so the inner product
  stays the plain uint8 x uint8 accumulation of eq. 9,
* int32 bias add (eq. 11),
* fixed-point requantization: SQRDMULH by the Q0.31 mantissa `m0` then a
  correctly-rounding right shift (eq. 6 / App. B),
* saturating cast to uint8 + fused activation clamp.

TPU mapping (DESIGN.md section Hardware-Adaptation): the grid tiles M and N
in 128-unit MXU-shaped blocks with K resident; VMEM per step is
bm*K + K*bn (u8) + bm*bn*4 (i32) which for bm = bn = 128 and K = 1024 is
about 0.3 MiB, far under the ~16 MiB VMEM budget, leaving room for double
buffering. On CPU we must run interpret=True (the real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute), so correctness is
validated through the interpret path against `ref.qmatmul_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles (128x128 systolic array).
DEFAULT_BLOCK = 128


def _srdhm(a, b):
    """SQRDMULH on int32 blocks (App. B), int64 intermediate."""
    ab = a.astype(jnp.int64) * b.astype(jnp.int64)
    nudge = jnp.where(ab >= 0, 1 << 30, 1 - (1 << 30)).astype(jnp.int64)
    total = ab + nudge
    # Truncating division toward zero.
    out = jnp.where(total >= 0, total // (1 << 31), -((-total) // (1 << 31)))
    sat = jnp.logical_and(a == jnp.int32(-(2**31)), b == jnp.int32(-(2**31)))
    return jnp.where(sat, jnp.int64(2**31 - 1), out).astype(jnp.int32)


def _rounding_shift(x, exponent: int):
    if exponent == 0:
        return x
    mask = jnp.int32((1 << exponent) - 1)
    remainder = jnp.bitwise_and(x, mask)
    threshold = (mask >> 1) + jnp.where(x < 0, 1, 0).astype(jnp.int32)
    return (x >> exponent) + jnp.where(remainder > threshold, 1, 0).astype(jnp.int32)


def _qmatmul_kernel(
    q1_ref,
    q2_ref,
    bias_ref,
    o_ref,
    *,
    k: int,
    z1: int,
    z2: int,
    m0: int,
    right_shift: int,
    z3: int,
    clamp_min: int,
    clamp_max: int,
):
    a1 = q1_ref[...].astype(jnp.int32)  # (bm, K) weights tile
    a2 = q2_ref[...].astype(jnp.int32)  # (K, bn) activations tile
    # eq. 9: the core integer accumulation — this is the MXU contraction.
    raw = jnp.dot(a1, a2)
    # eq. 7/8: O(N^2) zero-point corrections from row/col sums.
    row_sums = jnp.sum(a1, axis=1, keepdims=True)
    col_sums = jnp.sum(a2, axis=0, keepdims=True)
    acc = raw + jnp.int32(k * z1 * z2) - jnp.int32(z1) * col_sums - jnp.int32(z2) * row_sums
    # eq. 11 bias (int32, S_bias = S1*S2, Z_bias = 0).
    acc = acc + bias_ref[...].astype(jnp.int32)[:, None]
    # section 2.4 down-scale: fixed-point multiply + rounding shift.
    scaled = _rounding_shift(_srdhm(acc, jnp.full_like(acc, jnp.int32(m0))), right_shift)
    q = scaled + jnp.int32(z3)
    q = jnp.clip(q, 0, 255)
    q = jnp.clip(q, clamp_min, clamp_max)
    o_ref[...] = q.astype(jnp.uint8)


def qmatmul_pallas(
    q1,
    q2,
    z1: int,
    z2: int,
    bias,
    m0: int,
    right_shift: int,
    z3: int,
    clamp_min: int = 0,
    clamp_max: int = 255,
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
):
    """Tiled integer matmul `uint8[M,K] x uint8[K,N] -> uint8[M,N]`.

    Tile sizes clamp to the matrix dimensions; dimensions need not divide
    the block (Pallas pads the tail block and we mask via the grid index
    map's clamping in interpret mode).
    """
    m, k = q1.shape
    k2, n = q2.shape
    assert k == k2, (q1.shape, q2.shape)
    if bias is None:
        bias = jnp.zeros((m,), jnp.int32)
    bm = min(block_m, m)
    bn = min(block_n, n)
    # Grid must cover M and N exactly; require divisibility for the AOT
    # path (model shapes are chosen MXU-friendly), fall back to one tile
    # otherwise.
    if m % bm != 0 or n % bn != 0:
        bm, bn = m, n
    grid = (m // bm, n // bn)
    kernel = functools.partial(
        _qmatmul_kernel,
        k=k,
        z1=int(z1),
        z2=int(z2),
        m0=int(m0),
        right_shift=int(right_shift),
        z3=int(z3),
        clamp_min=int(clamp_min),
        clamp_max=int(clamp_max),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # weights row-panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # activations col-panel
            pl.BlockSpec((bm,), lambda i, j: (i,)),  # per-row bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q1, q2, bias)


def vmem_bytes_estimate(block_m: int, block_n: int, k: int) -> int:
    """Static VMEM footprint of one grid step (for DESIGN.md's roofline
    estimate): two uint8 operand panels plus the int32 accumulator tile."""
    return block_m * k + k * block_n + 4 * block_m * block_n
