"""L1 Pallas kernel: simulated quantization (eq. 12) with an STE gradient.

This is the op injected throughout the QAT training graph (fig. 1.1b,
"wt quant" / "act quant" nodes). The forward pass reproduces, in f32, the
exact rounding behaviour of the integer inference engine (nudged affine
parameters, clamp, round-to-nearest); the backward pass is the
straight-through estimator: gradients pass through where the input lies
inside the (nudged) representable range and are zero outside, matching
TensorFlow's FakeQuantWithMinMaxVars gradient.

All four quantization parameters (rmin, rmax, qmin, qmax) are *traced*
values packed into one length-4 vector operand, so a single compiled train
step can sweep bit depths (Tables 4.7/4.8) and the narrow weight range.

TPU mapping (DESIGN.md section Hardware-Adaptation): the kernel is purely
elementwise, so the BlockSpec tiles it along the leading axis in VMEM-sized
chunks; on CPU we run interpret=True, which lowers to the same HLO the
oracle produces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nudged(rmin, rmax, qmin, qmax):
    """Nudged (scale, zero_point); must mirror compile.quant.nudged_params."""
    rmin = jnp.minimum(rmin, 0.0)
    rmax = jnp.maximum(rmax, 0.0)
    degenerate = rmax - rmin < 1e-30
    scale = jnp.where(degenerate, 1.0, (rmax - rmin) / (qmax - qmin))
    zp = jnp.clip(jnp.round(qmin - rmin / scale), qmin, qmax)
    zp = jnp.where(degenerate, qmin, zp)
    return scale, zp


def _fake_quant_kernel(x_ref, qparams_ref, o_ref):
    x = x_ref[...]
    rmin, rmax, qmin, qmax = (
        qparams_ref[0],
        qparams_ref[1],
        qparams_ref[2],
        qparams_ref[3],
    )
    scale, zp = _nudged(rmin, rmax, qmin, qmax)
    q = jnp.clip(jnp.round(x / scale) + zp, qmin, qmax)
    o_ref[...] = (scale * (q - zp)).astype(x.dtype)


def fake_quant_pallas(x, rmin, rmax, qmin, qmax):
    """Raw Pallas forward (no gradient rule). All parameters traced."""
    qparams = jnp.stack(
        [
            jnp.asarray(rmin, jnp.float32),
            jnp.asarray(rmax, jnp.float32),
            jnp.asarray(qmin, jnp.float32),
            jnp.asarray(qmax, jnp.float32),
        ]
    ).reshape(4)
    return pl.pallas_call(
        _fake_quant_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, qparams)


@jax.custom_vjp
def fake_quant_ste(x, rmin, rmax, qmin, qmax):
    """Fake-quantize with the straight-through estimator."""
    return fake_quant_pallas(x, rmin, rmax, qmin, qmax)


def _fq_fwd(x, rmin, rmax, qmin, qmax):
    out = fake_quant_pallas(x, rmin, rmax, qmin, qmax)
    return out, (x, rmin, rmax, qmin, qmax)


def _fq_bwd(res, g):
    x, rmin, rmax, qmin, qmax = res
    scale, zp = _nudged(
        jnp.asarray(rmin, jnp.float32),
        jnp.asarray(rmax, jnp.float32),
        jnp.asarray(qmin, jnp.float32),
        jnp.asarray(qmax, jnp.float32),
    )
    lo = scale * (jnp.asarray(qmin, jnp.float32) - zp)
    hi = scale * (jnp.asarray(qmax, jnp.float32) - zp)
    mask = jnp.logical_and(x >= lo, x <= hi).astype(g.dtype)
    # Ranges are driven by min/max statistics and EMAs (section 3.1), not by
    # gradient descent, so they receive zero cotangents; so do the bit-depth
    # bounds.
    zeros = (
        jnp.zeros_like(jnp.asarray(rmin, jnp.float32)),
        jnp.zeros_like(jnp.asarray(rmax, jnp.float32)),
        jnp.zeros_like(jnp.asarray(qmin, jnp.float32)),
        jnp.zeros_like(jnp.asarray(qmax, jnp.float32)),
    )
    return (g * mask,) + zeros


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_weights_ste(w, bits: int = 8):
    """Weight fake-quant: range from min/max with the narrow-range tweak."""
    from compile import quant

    qmin, qmax = quant.quant_range(bits, narrow=True)
    rmin = jnp.min(jax.lax.stop_gradient(w))
    rmax = jnp.max(jax.lax.stop_gradient(w))
    return fake_quant_ste(w, rmin, rmax, jnp.float32(qmin), jnp.float32(qmax))
