"""AOT compile path: lower the L2 graphs to HLO *text* artifacts and export
initial parameters for the Rust L3 driver.

Run once via `make artifacts`; Python never runs again after this. The
interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact *set* is produced per PaperNet variant (architecture sweeps
for Table 4.1 and the latency-vs-accuracy figures), under
`artifacts/<variant>/`:

  train_step.hlo.txt   one QAT SGD-momentum step; traced knobs cover float
                       baseline, ReLU/ReLU6 and the bit-depth grid
  eval_float.hlo.txt   float logits (BN via EMA stats)
  eval_qsim.hlo.txt    quant-sim logits (Pallas fake-quant on activations)
  export_fold.hlo.txt  (params, bn) -> folded OHWI weights (eq. 14)
  params_init.bin      params + momenta + BN state + ranges (IAOI format)
  model_spec.txt       tensor ordering and model constants for the Rust side

plus `artifacts/quickstart.hlo.txt`, the standalone Pallas qmatmul kernel
(L1 -> HLO -> PJRT composition proof).
"""

from __future__ import annotations

import argparse
import os
import struct

import jax

jax.config.update("jax_enable_x64", True)  # the qmatmul kernel needs int64

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import quant
from compile import model
from compile.kernels import qmatmul


# Variant sets: depth sweep (Table 4.1) + width/resolution sweep (the
# latency-vs-accuracy figures). "base" is the default PaperNet.
VARIANTS: dict[str, model.Config] = {
    "base": model.Config(),
    "d2": model.Config(depth_blocks=2),
    "d3": model.Config(depth_blocks=3),
    "dm050_r16": model.Config(width_mult=0.5),
    "dm200_r16": model.Config(width_mult=2.0),
    "dm100_r24": model.Config(resolution=24),
    "dm200_r24": model.Config(width_mult=2.0, resolution=24),
    "dm100_r32": model.Config(resolution=32),
}


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Flat (positional) wrappers: the Rust side feeds literals positionally in
# the documented key order.
# ---------------------------------------------------------------------------


def unflatten(flat, keys):
    return {k: v for k, v in zip(keys, flat)}


def flatten(tree, keys):
    return [tree[k] for k in keys]


def make_flat_fns(cfg: model.Config):
    pk, bk, rk = cfg.param_keys(), cfg.bn_keys(), cfg.range_keys()
    n_p, n_b, n_r = len(pk), len(bk), len(rk)

    def train_step_flat(*args):
        params = unflatten(args[:n_p], pk)
        momenta = unflatten(args[n_p : 2 * n_p], pk)
        bn = unflatten(args[2 * n_p : 2 * n_p + n_b], bk)
        ranges = unflatten(args[2 * n_p + n_b : 2 * n_p + n_b + n_r], rk)
        x, labels, act_on, w_on, ceil, w_qmax, a_qmax = args[2 * n_p + n_b + n_r :]
        p2, m2, b2, r2, loss = model.train_step(
            params, momenta, bn, ranges, x, labels, act_on, w_on, ceil, w_qmax, a_qmax,
            config=cfg,
        )
        return tuple(
            flatten(p2, pk) + flatten(m2, pk) + flatten(b2, bk) + flatten(r2, rk) + [loss]
        )

    def eval_float_flat(*args):
        params = unflatten(args[:n_p], pk)
        bn = unflatten(args[n_p : n_p + n_b], bk)
        x, ceil = args[n_p + n_b :]
        ranges = model.init_ranges(cfg)  # unused when quantize=False
        return (
            model.eval_logits(params, bn, ranges, x, quantize=False, act_ceiling=ceil, config=cfg),
        )

    def eval_qsim_flat(*args):
        params = unflatten(args[:n_p], pk)
        bn = unflatten(args[n_p : n_p + n_b], bk)
        ranges = unflatten(args[n_p + n_b : n_p + n_b + n_r], rk)
        x, ceil, w_qmax, a_qmax = args[n_p + n_b + n_r :]
        # use_pallas=True: the L1 fake-quant kernel lowers into this artifact.
        return (
            model.eval_logits(
                params, bn, ranges, x,
                quantize=True, act_ceiling=ceil, w_qmax=w_qmax, a_qmax=a_qmax,
                use_pallas=True, config=cfg,
            ),
        )

    def export_fold_flat(*args):
        params = unflatten(args[:n_p], pk)
        bn = unflatten(args[n_p : n_p + n_b], bk)
        folded = model.export_folded(params, bn, config=cfg)
        return tuple(folded[k] for k in cfg.export_keys())

    return train_step_flat, eval_float_flat, eval_qsim_flat, export_fold_flat


# Quickstart: a standalone Pallas integer matmul, proving the L1 -> HLO ->
# PJRT composition end to end with fixed demo quantization parameters.
QUICKSTART_M, QUICKSTART_K, QUICKSTART_N = 4, 32, 4
QS_Z1, QS_Z2, QS_Z3 = 128, 120, 10
QS_M0, QS_SHIFT = quant.normalize_multiplier(0.002)


def quickstart_fn(q1, q2, bias):
    return (
        qmatmul.qmatmul_pallas(q1, q2, QS_Z1, QS_Z2, bias, QS_M0, QS_SHIFT, QS_Z3, 0, 255),
    )


# ---------------------------------------------------------------------------
# Parameter export (IAOI binary, mirrored by rust/src/io/mod.rs).
# ---------------------------------------------------------------------------


def write_iaoi(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(b"IAOI")
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", 0))  # dtype f32
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def emit_variant(out_dir: str, name: str, cfg: model.Config, seed: int) -> None:
    vdir = os.path.join(out_dir, name)
    os.makedirs(vdir, exist_ok=True)
    params = model.init_params(seed, cfg)
    bn = model.init_bn_state(cfg)
    ranges = model.init_ranges(cfg)
    momenta = model.init_momenta(params)
    pk, bk, rk = cfg.param_keys(), cfg.bn_keys(), cfg.range_keys()

    p_specs = [spec(params[k].shape) for k in pk]
    b_specs = [spec(bn[k].shape) for k in bk]
    r_specs = [spec((2,)) for _ in rk]
    x_spec = spec((cfg.batch, cfg.resolution, cfg.resolution, cfg.channels))
    y_spec = spec((cfg.batch,), jnp.int32)
    s = spec((), jnp.float32)

    train_fn, evalf_fn, evalq_fn, fold_fn = make_flat_fns(cfg)
    jobs = [
        ("train_step.hlo.txt", train_fn, p_specs + p_specs + b_specs + r_specs + [x_spec, y_spec, s, s, s, s, s]),
        ("eval_float.hlo.txt", evalf_fn, p_specs + b_specs + [x_spec, s]),
        ("eval_qsim.hlo.txt", evalq_fn, p_specs + b_specs + r_specs + [x_spec, s, s, s]),
        ("export_fold.hlo.txt", fold_fn, p_specs + b_specs),
    ]
    for fname, fn, specs in jobs:
        text = to_hlo_text(fn, specs)
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
    tensors: list[tuple[str, np.ndarray]] = []
    tensors += [(f"param:{k}", np.asarray(params[k])) for k in pk]
    tensors += [(f"mom:{k}", np.asarray(momenta[k])) for k in pk]
    tensors += [(f"bn:{k}", np.asarray(bn[k])) for k in bk]
    tensors += [(f"range:{k}", np.asarray(ranges[k])) for k in rk]
    write_iaoi(os.path.join(vdir, "params_init.bin"), tensors)

    spec_lines = [
        ("variant", name),
        ("depth_blocks", cfg.depth_blocks),
        ("width_mult", cfg.width_mult),
        ("conv_layer_count", cfg.conv_layer_count),
        ("resolution", cfg.resolution),
        ("channels", cfg.channels),
        ("num_classes", cfg.num_classes),
        ("batch", cfg.batch),
        ("act_quant_delay", model.ACT_QUANT_DELAY),
        ("learning_rate", model.LEARNING_RATE),
        ("momentum", model.MOMENTUM),
        ("n_params", len(pk)),
        ("n_bn", len(bk)),
        ("n_ranges", len(rk)),
        ("param_keys", ",".join(pk)),
        ("bn_keys", ",".join(bk)),
        ("range_keys", ",".join(rk)),
        ("export_keys", ",".join(cfg.export_keys())),
        ("train_scalars", "act_quant_on,w_quant_on,act_ceiling,w_qmax,a_qmax"),
    ]
    with open(os.path.join(vdir, "model_spec.txt"), "w") as f:
        for k, v in spec_lines:
            f.write(f"{k} = {v}\n")
    print(f"wrote artifact set {vdir} ({len(tensors)} init tensors)")


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument(
        "--variants",
        default="all",
        help="comma-separated variant names, or 'all' / 'base'",
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.variants == "all":
        selected = list(VARIANTS)
    elif args.variants == "base":
        selected = ["base"]
    else:
        selected = args.variants.split(",")
    for name in selected:
        emit_variant(args.out, name, VARIANTS[name], args.seed)

    # Quickstart kernel artifact + its demo constants.
    text = to_hlo_text(
        quickstart_fn,
        [
            spec((QUICKSTART_M, QUICKSTART_K), jnp.uint8),
            spec((QUICKSTART_K, QUICKSTART_N), jnp.uint8),
            spec((QUICKSTART_M,), jnp.int32),
        ],
    )
    with open(os.path.join(args.out, "quickstart.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(args.out, "quickstart_spec.txt"), "w") as f:
        f.write(f"mkn = {QUICKSTART_M},{QUICKSTART_K},{QUICKSTART_N}\n")
        f.write(f"zps = {QS_Z1},{QS_Z2},{QS_Z3}\n")
        f.write(f"multiplier = {QS_M0},{QS_SHIFT}\n")
    print(f"wrote {args.out}/quickstart.hlo.txt ({len(text)} chars)")


if __name__ == "__main__":
    main()
