"""Quantization math in JAX (build-time only).

Implements the paper's quantization scheme (eq. 1, eq. 12-13) in jnp so the
L2 training graph simulates *exactly* the arithmetic of the Rust inference
engine (`rust/src/quant`): nudged affine parameters with an exactly
representable real zero, narrow-range weights (int8 never takes -128,
App. B), and the B-bit generalization used by the bit-depth ablations
(Tables 4.7/4.8).

Everything here is pure and differentiable-friendly; the straight-through
estimator lives with the fake-quant kernels in `kernels/`.
"""

from __future__ import annotations

import jax.numpy as jnp

UINT8_MAX = 255.0


def quant_range(bits: int, narrow: bool) -> tuple[float, float]:
    """Quantized range [qmin, qmax] for B-bit storage.

    `narrow=True` drops the lowest code so symmetric int8 weights avoid
    -128, enabling the App. B int16-pairwise trick.
    """
    assert 2 <= bits <= 8, bits
    return (1.0 if narrow else 0.0), float(2**bits - 1)


def nudged_params(rmin, rmax, qmin: float, qmax: float):
    """Scale and zero-point from an observed real range (eq. 13).

    The range is widened to include 0.0 and the zero-point is rounded to an
    integer so real 0.0 is exactly representable (the zero-padding
    requirement of section 2.1). Mirrors
    `rust/src/quant/mod.rs::QuantParams::from_min_max` bit-for-bit at f64.
    """
    rmin = jnp.minimum(rmin, 0.0)
    rmax = jnp.maximum(rmax, 0.0)
    degenerate = rmax - rmin < 1e-30
    scale = jnp.where(degenerate, 1.0, (rmax - rmin) / (qmax - qmin))
    zp_real = qmin - rmin / scale
    zero_point = jnp.clip(jnp.round(zp_real), qmin, qmax)
    zero_point = jnp.where(degenerate, qmin, zero_point)
    return scale, zero_point


def fake_quant_reference(x, rmin, rmax, qmin: float, qmax: float):
    """Eq. 12: clamp -> affine quantize -> round -> dequantize, in f32.

    The pure-jnp oracle for the Pallas kernel and the forward arithmetic of
    simulated-quantization training (fig. 1.1b).
    """
    scale, zero_point = nudged_params(rmin, rmax, qmin, qmax)
    q = jnp.clip(jnp.round(x / scale) + zero_point, qmin, qmax)
    return scale * (q - zero_point)


def quantize_reference(x, rmin, rmax, qmin: float, qmax: float):
    """Integer codes (as f32 values) for `x` under the nudged parameters."""
    scale, zero_point = nudged_params(rmin, rmax, qmin, qmax)
    return jnp.clip(jnp.round(x / scale) + zero_point, qmin, qmax)


def weight_range(w):
    """Weight quantization range: a := min w, b := max w (section 3.1)."""
    return jnp.min(w), jnp.max(w)


def fake_quant_weights(w, bits: int = 8):
    """Weight fake-quantization with the narrow-range tweak (section 3.1)."""
    qmin, qmax = quant_range(bits, narrow=True)
    rmin, rmax = weight_range(w)
    return fake_quant_reference(w, rmin, rmax, qmin, qmax)


def ema_update(old_min, old_max, batch_min, batch_max, decay: float):
    """Section 3.1 activation-range EMA ('smoothing parameter close to 1')."""
    new_min = decay * old_min + (1.0 - decay) * batch_min
    new_max = decay * old_max + (1.0 - decay) * batch_max
    return new_min, new_max


def normalize_multiplier(m: float) -> tuple[int, int]:
    """Offline eq. 6 normalization M = 2^-n * M0 (python ints, build path).

    Returns (m0_q31, right_shift) exactly like
    `rust/src/quant/multiplier.rs::QuantizedMultiplier::from_f64`.
    """
    assert m > 0.0, m
    shift = 0
    m0 = float(m)
    while m0 < 0.5:
        m0 *= 2.0
        shift -= 1
    while m0 >= 1.0:
        m0 /= 2.0
        shift += 1
    q = int(round(m0 * (1 << 31)))
    if q == 1 << 31:
        q //= 2
        shift += 1
    assert (1 << 30) <= q < (1 << 31)
    return q, -shift


def srdhm(a, b):
    """SQRDMULH on int32 jnp arrays (App. B), matching `fixedpoint::srdhm`."""
    a64 = a.astype(jnp.int64)
    b64 = b.astype(jnp.int64)
    ab = a64 * b64
    nudge = jnp.where(ab >= 0, 1 << 30, 1 - (1 << 30)).astype(jnp.int64)
    # Truncating division toward zero, as in the C++ reference.
    out = (ab + nudge) // (1 << 31)
    out = jnp.where((ab + nudge) < 0, -((-(ab + nudge)) // (1 << 31)), out)
    sat = (a == jnp.int32(-(2**31))) & (b == jnp.int32(-(2**31)))
    return jnp.where(sat, jnp.int32(2**31 - 1), out.astype(jnp.int32))


def rounding_div_by_pot(x, exponent: int):
    """Round-to-nearest (ties away from zero) right shift, per App. B."""
    if exponent == 0:
        return x
    mask = jnp.int32((1 << exponent) - 1)
    remainder = jnp.bitwise_and(x, mask)
    threshold = (mask >> 1) + jnp.where(x < 0, 1, 0).astype(jnp.int32)
    return (x >> exponent) + jnp.where(remainder > threshold, 1, 0).astype(jnp.int32)


def apply_multiplier(acc, m0: int, right_shift: int):
    """Integer requantization: srdhm by m0 then rounding right shift."""
    return rounding_div_by_pot(srdhm(acc, jnp.int32(m0)), right_shift)
