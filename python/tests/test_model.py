"""L2 model tests: shapes, BN-fold equivalence, QAT training sanity and the
pallas/ref path equality inside the full forward."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model


def synth_batch(seed: int, batch: int = model.BATCH):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.uniform(-1, 1, (batch, model.RESOLUTION, model.RESOLUTION, model.CHANNELS)),
        jnp.float32,
    )
    # Learnable toy labels: mean-brightness quadrant + channel dominance.
    means = np.asarray(x).mean(axis=(1, 2))  # [B, C]
    labels = (
        (means[:, 0] > 0).astype(np.int32) * 8
        + (means[:, 1] > 0).astype(np.int32) * 4
        + (means[:, 2] > 0).astype(np.int32) * 2
        + (np.asarray(x)[:, :8].mean(axis=(1, 2, 3)) > 0).astype(np.int32)
    )
    return x, jnp.asarray(labels % model.NUM_CLASSES, jnp.int32)


def fresh_state(seed=0):
    p = model.init_params(seed)
    return p, model.init_momenta(p), model.init_bn_state(), model.init_ranges()


def test_forward_shapes():
    p, _, bn, rg = fresh_state()
    x, _ = synth_batch(0)
    logits, new_bn, new_rg = model.forward(
        p, bn, rg, x, training=False, quantize=False, act_quant_on=jnp.float32(0.0)
    )
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)
    # Eval must not mutate state.
    for k in bn:
        np.testing.assert_array_equal(np.asarray(new_bn[k]), np.asarray(bn[k]))


def test_param_counts_match_spec():
    p, _, bn, rg = fresh_state()
    assert sorted(p.keys()) == sorted(model.PARAM_KEYS)
    assert sorted(bn.keys()) == sorted(model.BN_KEYS)
    assert sorted(rg.keys()) == sorted(model.RANGE_KEYS)
    shapes = model.param_shapes()
    for k, v in p.items():
        assert tuple(v.shape) == shapes[k], k


def test_train_step_decreases_loss():
    """Loss must trend down over QAT steps (memorization of a small fixed
    set) — the end-to-end signal that STE gradients and folding are sane."""
    p, m, bn, rg = fresh_state(1)
    step = jax.jit(
        lambda p, m, bn, rg, x, y, on: model.train_step(p, m, bn, rg, x, y, on)
    )
    batches = [synth_batch(i) for i in range(4)]
    first = None
    last = None
    for i in range(120):
        x, y = batches[i % 4]
        act_on = jnp.float32(1.0 if i >= 20 else 0.0)  # scaled-down delay
        p, m, bn, rg, loss = step(p, m, bn, rg, x, y, act_on)
        if i < 4:
            first = float(loss) if first is None else max(first, float(loss))
        last = float(loss)
    assert last < first * 0.7, f"loss did not decrease: first {first}, last {last}"


def test_ranges_update_only_in_training():
    p, m, bn, rg = fresh_state(2)
    x, y = synth_batch(3)
    _, _, _, rg2, _ = model.train_step(p, m, bn, rg, x, y, jnp.float32(1.0))
    moved = any(
        float(jnp.max(jnp.abs(rg2[k] - rg[k]))) > 0 for k in model.RANGE_KEYS
    )
    assert moved, "EMA ranges must move during training"


def test_qsim_eval_matches_float_when_ranges_are_wide():
    """With effectively-disabled quantization (huge ranges, 8-bit), the
    quant-sim logits approximate the float logits coarsely; with trained
    tight ranges they should be close. Here: check the wiring by comparing
    quant-sim against itself through the pallas and ref paths (bit-equal up
    to float ulps)."""
    p, _, bn, rg = fresh_state(4)
    x, _ = synth_batch(5, batch=4)
    ref_logits = model.eval_logits(p, bn, rg, x, quantize=True, use_pallas=False)
    pal_logits = model.eval_logits(p, bn, rg, x, quantize=True, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(ref_logits), np.asarray(pal_logits), rtol=0, atol=1e-4
    )


def test_folded_training_matches_eval_semantics():
    """After training steps, eval_float with EMA stats must be consistent
    with the folded export: running the folded weights manually reproduces
    eval_float's logits (fig. C.6 == eq. 14 folding)."""
    p, m, bn, rg = fresh_state(6)
    for i in range(5):
        x, y = synth_batch(10 + i)
        p, m, bn, rg, _ = model.train_step(p, m, bn, rg, x, y, jnp.float32(0.0))
    x, _ = synth_batch(99, batch=4)
    want = model.eval_logits(p, bn, rg, x, quantize=False)

    folded = model.export_folded(p, bn)
    h = x
    for name, kind, stride, _cin, _cout in model.LAYERS:
        w = folded[f"{name}/w"]
        if kind == "conv":
            w_hwio = jnp.transpose(w, (1, 2, 3, 0))  # OHWI -> HWIO
        else:
            w_hwio = jnp.transpose(w, (1, 2, 0, 3))  # 1HWC -> HW1C
        h = model._conv(h, w_hwio, stride, kind == "dw") + folded[f"{name}/b"]
        h = jnp.clip(h, 0.0, 6.0)
    h = jnp.mean(h, axis=(1, 2))
    got = h @ jnp.transpose(folded["fc/w"]) + folded["fc/b"]
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), rtol=0, atol=1e-4)


def test_export_shapes_are_rust_layouts():
    p, _, bn, _ = fresh_state(7)
    folded = model.export_folded(p, bn)
    assert folded["conv0/w"].shape == (8, 3, 3, 3)  # OHWI
    assert folded["dw1/w"].shape == (1, 3, 3, 8)  # 1HWC
    assert folded["pw2/w"].shape == (32, 1, 1, 16)
    assert folded["fc/w"].shape == (model.NUM_CLASSES, model.FC_IN)
    assert set(folded.keys()) == set(model.EXPORT_KEYS)


def test_relu_variant_runs():
    # Table 4.3's ReLU-vs-ReLU6 comparison: the activation ceiling is a
    # traced scalar (6.0 for ReLU6, huge for ReLU).
    p, m, bn, rg = fresh_state(8)
    x, y = synth_batch(1)
    out = model.train_step(
        p, m, bn, rg, x, y, jnp.float32(1.0), act_ceiling=jnp.float32(model.RELU_CEIL)
    )
    assert np.isfinite(float(out[-1]))


def test_bit_depth_variants_run():
    # Tables 4.7/4.8: 4..8-bit weight/activation combinations must train;
    # bit depths enter as traced qmax scalars.
    p, m, bn, rg = fresh_state(9)
    x, y = synth_batch(2)
    for wb, ab in [(8, 8), (7, 7), (4, 8), (8, 4), (4, 4)]:
        out = model.train_step(
            p, m, bn, rg, x, y,
            jnp.float32(1.0),
            w_qmax=jnp.float32(2**wb - 1),
            a_qmax=jnp.float32(2**ab - 1),
        )
        assert np.isfinite(float(out[-1])), (wb, ab)


def test_float_baseline_via_traced_knobs():
    # w_quant_on = act_quant_on = 0 turns the same step into float training.
    p, m, bn, rg = fresh_state(10)
    x, y = synth_batch(3)
    p2, _, _, _, loss = model.train_step(
        p, m, bn, rg, x, y, jnp.float32(0.0), jnp.float32(0.0)
    )
    assert np.isfinite(float(loss))
    moved = any(float(jnp.max(jnp.abs(p2[k] - p[k]))) > 0 for k in p)
    assert moved


def test_depth_and_width_variants():
    # Config-driven family (Table 4.1 depths, figure DM sweep).
    for cfg in [
        model.Config(depth_blocks=2),
        model.Config(width_mult=0.5),
        model.Config(width_mult=2.0, resolution=24),
    ]:
        p = model.init_params(0, cfg)
        bn = model.init_bn_state(cfg)
        rg = model.init_ranges(cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(
            rng.uniform(-1, 1, (2, cfg.resolution, cfg.resolution, cfg.channels)),
            jnp.float32,
        )
        logits = model.eval_logits(p, bn, rg, x, quantize=True, config=cfg)
        assert logits.shape == (2, cfg.num_classes)
    assert model.Config(depth_blocks=2).conv_layer_count == model.DEFAULT.conv_layer_count + 2
