"""Properties of the quantization math shared between L1/L2 and the Rust
engine (`compile.quant` mirrors `rust/src/quant`)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


@settings(max_examples=60, deadline=None)
@given(
    rmin=st.floats(-100.0, 100.0),
    rmax=st.floats(-100.0, 100.0),
    bits=st.integers(2, 8),
    narrow=st.booleans(),
)
def test_zero_exactly_representable(rmin, rmax, bits, narrow):
    """Section 2.1: the real value 0.0 must map to an integer code with no
    quantization error — for any observed range."""
    if rmax < rmin:
        rmin, rmax = rmax, rmin
    qmin, qmax = quant.quant_range(bits, narrow)
    scale, zp = quant.nudged_params(jnp.float64(rmin), jnp.float64(rmax), qmin, qmax)
    assert float(zp) == round(float(zp))  # integer zero-point
    assert qmin <= float(zp) <= qmax
    fq0 = quant.fake_quant_reference(jnp.float64(0.0), jnp.float64(rmin), jnp.float64(rmax), qmin, qmax)
    assert float(fq0) == 0.0


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rmin=st.floats(-10.0, -0.1),
    rmax=st.floats(0.1, 10.0),
)
def test_fake_quant_error_bounded(seed, rmin, rmax):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(rmin, rmax, (64,)), jnp.float64)
    out = quant.fake_quant_reference(x, jnp.float64(rmin), jnp.float64(rmax), 0.0, 255.0)
    scale = (max(rmax, 0.0) - min(rmin, 0.0)) / 255.0
    # Interior points are within scale/2; the zero-nudge adds at most
    # another scale/2 near the boundaries.
    assert float(jnp.max(jnp.abs(out - x))) <= scale + 1e-12


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(2, 8))
def test_weight_fake_quant_narrow_range(seed, bits):
    """Section 3.1/App. B: quantized weights must avoid the lowest code, so
    the int8 view never takes -128."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (128,)), jnp.float64)
    qmin, qmax = quant.quant_range(bits, narrow=True)
    rmin, rmax = quant.weight_range(w)
    codes = quant.quantize_reference(w, rmin, rmax, qmin, qmax)
    assert float(jnp.min(codes)) >= 1.0
    assert float(jnp.max(codes)) <= float(2**bits - 1)


@settings(max_examples=60, deadline=None)
@given(m=st.floats(1e-6, 0.999999))
def test_normalize_multiplier_eq6(m):
    """Eq. 6 invariants: m0 in [0.5, 1) as Q0.31 with >= 30 bits of
    relative accuracy, non-negative shift count."""
    m0, right_shift = quant.normalize_multiplier(m)
    assert (1 << 30) <= m0 < (1 << 31)
    assert right_shift >= 0
    reconstructed = m0 / 2**31 * 2**-right_shift
    assert abs(reconstructed - m) / m < 1e-9


def test_ema_matches_paper_semantics():
    mn, mx = quant.ema_update(
        jnp.float32(-1.0), jnp.float32(1.0), jnp.float32(-3.0), jnp.float32(3.0), 0.9
    )
    assert abs(float(mn) + 1.2) < 1e-6
    assert abs(float(mx) - 1.2) < 1e-6


@settings(max_examples=60, deadline=None)
@given(a=st.integers(-(2**31), 2**31 - 1), b=st.integers(-(2**31), 2**31 - 1))
def test_srdhm_matches_int_reference(a, b):
    """jnp srdhm == the integer reference == the Rust `fixedpoint::srdhm`."""
    got = int(quant.srdhm(jnp.int32(a), jnp.int32(b)))
    if a == -(2**31) and b == -(2**31):
        want = 2**31 - 1
    else:
        ab = a * b
        nudge = (1 << 30) if ab >= 0 else 1 - (1 << 30)
        total = ab + nudge
        want = total // (1 << 31) if total >= 0 else -((-total) // (1 << 31))
    assert got == want, (a, b, got, want)


@settings(max_examples=60, deadline=None)
@given(x=st.integers(-(2**31), 2**31 - 1), e=st.integers(1, 20))
def test_rounding_shift_matches_round_half_away(x, e):
    got = int(quant.rounding_div_by_pot(jnp.int32(x), e))
    exact = x / 2**e
    frac = exact - int(exact)
    if abs(frac) == 0.5:
        want = int(exact) + (1 if exact > 0 else -1)
    else:
        want = round(exact)
    assert got == want, (x, e, got, want)
