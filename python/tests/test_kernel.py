"""Kernel vs reference oracle — the CORE correctness signal for L1.

hypothesis sweeps shapes, ranges and bit depths; every Pallas kernel output
must match the pure-jnp oracle exactly (same float ops) or within one LSB
where integer rounding orders differ (they don't: bit-exact asserts below).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import fake_quant as fq
from compile.kernels import qmatmul as qm
from compile.kernels import ref


# ---------------------------------------------------------------------------
# fake_quant kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 17),
    cols=st.integers(1, 33),
    rmin=st.floats(-8.0, -0.01),
    rmax=st.floats(0.01, 8.0),
    bits=st.integers(4, 8),
    narrow=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_pallas_matches_ref(rows, cols, rmin, rmax, bits, narrow, seed):
    qmin, qmax = quant.quant_range(bits, narrow)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(rmin * 1.5, rmax * 1.5, (rows, cols)), jnp.float32)
    got = fq.fake_quant_pallas(x, jnp.float32(rmin), jnp.float32(rmax), qmin, qmax)
    want = ref.fake_quant_ref(x, jnp.float32(rmin), jnp.float32(rmax), qmin, qmax)
    # XLA (ref) and interpret-mode numpy (pallas) may differ by float ulps in
    # the scale computation; any *code* disagreement would show up as a full
    # quantization-step difference, far above this tolerance.
    scale = (max(rmax, 0.0) - min(rmin, 0.0)) / (qmax - qmin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=scale * 1e-3)


def test_fake_quant_zero_is_exact():
    # Section 2.1: real 0.0 must be exactly representable after quantization.
    for rmin, rmax in [(-1.0, 1.0), (-0.3, 2.7), (-6.0, 0.5)]:
        out = fq.fake_quant_pallas(
            jnp.zeros((4, 4), jnp.float32), jnp.float32(rmin), jnp.float32(rmax), 0.0, 255.0
        )
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_fake_quant_is_idempotent():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(-2, 2, (8, 8)), jnp.float32)
    once = fq.fake_quant_pallas(x, jnp.float32(-1.5), jnp.float32(1.5), 0.0, 255.0)
    twice = fq.fake_quant_pallas(once, jnp.float32(-1.5), jnp.float32(1.5), 0.0, 255.0)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_fake_quant_ste_gradient_is_masked_passthrough():
    # Nudged range for [-1, 1] is [-0.9961, 1.0039] (zero-point 127), so
    # -1.0 falls just outside while +1.0 falls inside.
    x = jnp.asarray([-10.0, -0.99, 0.0, 0.5, 1.0, 10.0], jnp.float32)
    rmin, rmax = jnp.float32(-1.0), jnp.float32(1.0)

    def f(v):
        return jnp.sum(fq.fake_quant_ste(v, rmin, rmax, 0.0, 255.0))

    g = jax.grad(f)(x)
    # Inside the representable range: gradient 1; outside: 0.
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 1, 1, 0], atol=1e-6)


def test_fake_quant_range_gradients_are_zero():
    x = jnp.ones((3,), jnp.float32)

    def f(rmin, rmax):
        return jnp.sum(fq.fake_quant_ste(x, rmin, rmax, 0.0, 255.0))

    g1, g2 = jax.grad(f, argnums=(0, 1))(jnp.float32(-1.0), jnp.float32(2.0))
    assert float(g1) == 0.0 and float(g2) == 0.0


# ---------------------------------------------------------------------------
# qmatmul kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 64),
    n=st.integers(1, 24),
    z1=st.integers(0, 255),
    z2=st.integers(0, 255),
    z3=st.integers(0, 255),
    mult=st.floats(1e-4, 0.99),
    use_bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_pallas_matches_ref(m, k, n, z1, z2, z3, mult, use_bias, seed):
    rng = np.random.default_rng(seed)
    q1 = jnp.asarray(rng.integers(1, 256, (m, k)), jnp.uint8)  # narrow weights
    q2 = jnp.asarray(rng.integers(0, 256, (k, n)), jnp.uint8)
    bias = jnp.asarray(rng.integers(-5000, 5000, (m,)), jnp.int32) if use_bias else None
    m0, shift = quant.normalize_multiplier(mult)
    got = qm.qmatmul_pallas(q1, q2, z1, z2, bias, m0, shift, z3)
    want = ref.qmatmul_ref(q1, q2, z1, z2, bias, m0, shift, z3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_qmatmul_tiled_grid_matches_single_tile(seed):
    # Shapes that exercise the (M//bm, N//bn) grid with multiple tiles.
    rng = np.random.default_rng(seed)
    m, k, n = 8, 16, 12
    q1 = jnp.asarray(rng.integers(1, 256, (m, k)), jnp.uint8)
    q2 = jnp.asarray(rng.integers(0, 256, (k, n)), jnp.uint8)
    m0, shift = quant.normalize_multiplier(0.01)
    tiled = qm.qmatmul_pallas(q1, q2, 100, 90, None, m0, shift, 7, block_m=4, block_n=4)
    single = qm.qmatmul_pallas(q1, q2, 100, 90, None, m0, shift, 7, block_m=8, block_n=12)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(single))


def test_qmatmul_integer_path_tracks_real_arithmetic():
    # Dequantized integer output must be within one output LSB of the
    # real-number computation (the section 2.2 guarantee).
    rng = np.random.default_rng(3)
    m, k, n = 6, 40, 5
    s1, s2, s3 = 0.007, 0.02, 0.05
    z1, z2, z3 = 128, 110, 15
    q1 = jnp.asarray(rng.integers(1, 256, (m, k)), jnp.uint8)
    q2 = jnp.asarray(rng.integers(0, 256, (k, n)), jnp.uint8)
    m0, shift = quant.normalize_multiplier(s1 * s2 / s3)
    got = qm.qmatmul_pallas(q1, q2, z1, z2, None, m0, shift, z3)
    want = ref.qmatmul_float_view(q1, q2, s1, s2, z1, z2, None, s3, z3)
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert diff.max() <= 1, f"max LSB diff {diff.max()}"


def test_qmatmul_rejects_mismatched_k():
    with pytest.raises(AssertionError):
        qm.qmatmul_pallas(
            jnp.zeros((2, 3), jnp.uint8), jnp.zeros((4, 2), jnp.uint8), 0, 0, None, 1 << 30, 1, 0
        )


def test_vmem_estimate_is_under_budget():
    # DESIGN.md section Perf: default MXU tiles with K = 1024 stay well
    # under a 16 MiB VMEM budget, with room for double buffering.
    bytes_ = qm.vmem_bytes_estimate(qm.DEFAULT_BLOCK, qm.DEFAULT_BLOCK, 1024)
    assert bytes_ * 2 < 16 * 1024 * 1024, bytes_


# ---------------------------------------------------------------------------
# integer helpers vs the Rust semantics (same constants as fixedpoint tests)
# ---------------------------------------------------------------------------


def test_srdhm_matches_fixedpoint_reference_cases():
    cases = [(1 << 30, 1 << 30, 1 << 29), (0, -(2**31), 0)]
    for a, b, want in cases:
        got = int(quant.srdhm(jnp.int32(a), jnp.int32(b)))
        assert got == want, (a, b, got, want)
    sat = int(quant.srdhm(jnp.int32(-(2**31)), jnp.int32(-(2**31))))
    assert sat == 2**31 - 1


def test_rounding_shift_ties_away_from_zero():
    # The App. B example: -12 >> 3 must round to -2, not -1.
    assert int(quant.rounding_div_by_pot(jnp.int32(-12), 3)) == -2
    assert int(quant.rounding_div_by_pot(jnp.int32(12), 3)) == 2
    assert int(quant.rounding_div_by_pot(jnp.int32(-11), 3)) == -1


@settings(max_examples=50, deadline=None)
@given(acc=st.integers(-(2**30), 2**30), mult=st.floats(1e-5, 0.999))
def test_apply_multiplier_tracks_real_product(acc, mult):
    m0, shift = quant.normalize_multiplier(mult)
    got = int(quant.apply_multiplier(jnp.int32(acc), m0, shift))
    want = round(acc * mult)
    assert abs(got - want) <= 1, (acc, mult, got, want)
