//! Serving example: run the integer-only model behind the dynamic-batching
//! coordinator and drive it with a bursty closed-loop workload, reporting
//! latency percentiles, realized batch sizes and throughput — the serving
//! shape of the paper's latency story (§4.2).
//!
//! Run: `cargo run --release --example serve [requests]`
//! (works without artifacts: uses a PTQ-quantized random model when no
//! trained model is present)

use anyhow::Result;
use iaoi::coordinator::{BatchPolicy, Coordinator, EngineKind};
use iaoi::data::{ClassificationSet, Rng};
use iaoi::graph::builders::papernet_random;
use iaoi::nn::FusedActivation;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);

    // Build an int8 engine (PTQ of a random model keeps the example
    // self-contained; `iaoi serve` uses the QAT-trained weights).
    let float_model = papernet_random(16, FusedActivation::Relu6, 3);
    let mut rng = Rng::seeded(9);
    let calib: Vec<Tensor<f32>> = (0..3)
        .map(|_| {
            let mut d = vec![0f32; 2 * 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[2, 16, 16, 3], d)
        })
        .collect();
    let (folded, int8_model) = quantize_graph(&float_model, &calib, QuantizeOptions::default());

    let ds = ClassificationSet::new(16, 16, 11);
    for (label, engine) in [
        ("int8", EngineKind::Quant(Arc::new(int8_model))),
        ("float32", EngineKind::Float(Arc::new(folded))),
    ] {
        for max_batch in [1usize, 8] {
            let policy = BatchPolicy { max_batch, max_delay: Duration::from_millis(1) };
            let coord = Coordinator::start(engine.clone(), policy, 1);
            let client = coord.client();
            let start = Instant::now();
            // Bursty open-ish loop: issue in bursts of 16, await each burst.
            let mut done = 0usize;
            while done < requests {
                let burst: Vec<_> = (0..16.min(requests - done))
                    .map(|i| {
                        let (img, _) = ds.example(3, (done + i) as u64);
                        client.submit(img).expect("submit")
                    })
                    .collect();
                done += burst.len();
                for (_, rx) in burst {
                    rx.recv().expect("response");
                }
            }
            let wall = start.elapsed().as_secs_f64();
            let m = coord.shutdown();
            println!("{}", m.summary());
            println!(
                "  engine={label} max_batch={max_batch} -> {:.0} req/s",
                requests as f64 / wall
            );
        }
    }
    println!("serve example OK — compare int8 vs float32 throughput and the max_batch=1 vs 8 batching win");
    Ok(())
}
