//! Multi-model serving example: two quantized models exported as `.iaoiq`
//! artifacts, loaded into a [`ModelRegistry`], and served *concurrently*
//! through the multi-model coordinator — then one of them is **hot-swapped
//! to a new version mid-run** without dropping a single in-flight request.
//! This is the paper's deployment story (serialize once, serve the
//! artifact) pushed to the ROADMAP's serving shape.
//!
//! Run: `cargo run --release --example serve [requests-per-model]`
//! (fully self-contained: models are PTQ-quantized on the fly and written
//! to a temp directory)

use anyhow::Result;
use iaoi::coordinator::registry::{ModelRegistry, QuarantineConfig};
use iaoi::coordinator::{BatchPolicy, MultiCoordinator};
use iaoi::data::ClassificationSet;
use iaoi::graph::fault::FaultPlan;
use iaoi::harness::demo_artifact;
use iaoi::model_format;
use iaoi::serve::client::HttpClient;
use iaoi::serve::{ServeConfig, Server};
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);

    // --- Export two distinct models as .iaoiq artifacts. ---
    let dir = std::env::temp_dir().join(format!("iaoi-serve-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    // alpha: 16-class classifier; beta: 8-class (different output arity
    // makes cross-model routing mistakes impossible to miss).
    model_format::write_file(&dir.join("alpha.iaoiq"), &demo_artifact("alpha", 1, 16, 3))?;
    model_format::write_file(&dir.join("beta.iaoiq"), &demo_artifact("beta", 1, 8, 11))?;
    // alpha v2 (retrained stand-in: different seed => different weights),
    // exported up front so the swap below is just a registry call.
    let alpha_v2 = dir.join("alpha_v2.iaoiq");
    model_format::write_file(&alpha_v2, &demo_artifact("alpha", 2, 16, 42))?;

    // --- Load the registry and start serving. ---
    let registry = ModelRegistry::load_dir(&dir)?;
    // load_dir already prefers the highest version per name; for the demo,
    // roll alpha back to v1 so the mid-run swap has something to do.
    registry.swap("alpha", &dir.join("alpha.iaoiq"))?;
    println!("serving models: {:?}", registry.names());

    let policy = BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(1), ..Default::default() };
    let coord = MultiCoordinator::start(registry.clone(), policy, 2);
    let start = Instant::now();

    // --- Drive both models from concurrent client threads. ---
    let results = std::thread::scope(|s| {
        let handles: Vec<_> = [("alpha", 16usize), ("beta", 8usize)]
            .into_iter()
            .map(|(name, classes)| {
                let client = coord.client();
                s.spawn(move || {
                    let ds = ClassificationSet::new(16, classes, 5);
                    let mut versions = BTreeSet::new();
                    let mut completed = 0usize;
                    let mut done = 0usize;
                    while done < requests {
                        let burst: Vec<_> = (0..16.min(requests - done))
                            .map(|i| {
                                let (img, _) = ds.example(2, (done + i) as u64);
                                client.submit(name, img).expect("submit")
                            })
                            .collect();
                        done += burst.len();
                        for (id, rx) in burst {
                            let resp = rx.recv().expect("response");
                            assert_eq!(resp.id, id);
                            assert_eq!(resp.model, name);
                            assert_eq!(resp.output().len(), classes, "routing mixed models!");
                            versions.insert(resp.version);
                            completed += 1;
                        }
                    }
                    (name, completed, versions)
                })
            })
            .collect();

        // --- Hot-swap alpha to v2 while both clients are mid-run. ---
        std::thread::sleep(Duration::from_millis(5));
        let (old, new) = registry.swap("alpha", &alpha_v2).expect("hot swap");
        println!("hot-swapped alpha v{old:?} -> v{new} at t={:?}", start.elapsed());
        assert_eq!((old, new), (Some(1), 2));

        handles.into_iter().map(|h| h.join().expect("client thread")).collect::<Vec<_>>()
    });

    // Post-swap, new traffic must deterministically land on alpha v2 while
    // beta keeps serving v1.
    let probe = ClassificationSet::new(16, 16, 9);
    let resp = coord.client().infer("alpha", probe.example(2, 0).0)?;
    assert_eq!((resp.version, resp.output().len()), (2, 16), "post-swap alpha must serve v2");

    let wall = start.elapsed().as_secs_f64();
    for m in coord.shutdown() {
        println!("{}", m.summary());
    }
    let mut total = 0usize;
    for (name, completed, versions) in results {
        total += completed;
        println!("  {name}: {completed}/{requests} completed, served by version(s) {versions:?}");
        assert_eq!(completed, requests, "{name} dropped requests");
        if name == "beta" {
            assert_eq!(versions, BTreeSet::from([1]), "beta must be untouched by alpha's swap");
        }
    }
    println!(
        "serve example OK — {total} requests across 2 models in {wall:.2}s ({:.0} req/s), \
         one model hot-swapped mid-run with zero dropped requests",
        total as f64 / wall
    );

    // --- The same artifacts through the socket front end. ---
    // `iaoi serve --addr HOST:PORT` wraps this Server; the in-process
    // handle shows the production rails end to end: an HTTP round trip, a
    // clean admission shed at the in-flight cap, and a graceful drain.
    let registry = ModelRegistry::load_dir(&dir)?;
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(1),
        global_inflight_cap: 4,
        ..Default::default()
    };
    let server = Server::start(registry, policy, 2, ServeConfig::default())?;
    let addr = server.local_addr();
    let mut http = HttpClient::connect(addr)?;
    println!("\nsocket front end on http://{addr}: healthz {}", http.get("/healthz")?.status);
    // Serving always runs prepared plans, so any conv→Add chain in an
    // installed model is folded into a fused GEMM epilogue at install time
    // (`IAOI_FUSION=off` opts out fleet-wide); `/healthz` reports the
    // per-model `fused_nodes` count. The demo papernet has no residual
    // Adds, so it reports 0 — a resnet-style artifact would report one per
    // folded skip connection.
    let health = http.get("/healthz")?.body_text();
    assert!(health.contains("\"fused_nodes\":0"), "healthz must report fusion: {health}");
    let probe = ClassificationSet::new(16, 16, 9);
    let resp = http.infer("alpha", probe.example(2, 0).0.data())?;
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body_f32()?.len(), 16);
    println!(
        "  POST /infer/alpha -> 200 (served by v{}, {}us)",
        resp.header("X-Model-Version").unwrap_or("?"),
        resp.header("X-Latency-Us").unwrap_or("?"),
    );
    // Saturate admission to show load-shedding, then drain out.
    let admission = server.admission();
    let permits: Vec<_> =
        (0..4).map(|_| admission.try_acquire("alpha").expect("cap slot")).collect();
    let shed = http.infer("alpha", probe.example(2, 1).0.data())?;
    assert_eq!(shed.status, 503, "past the cap, arrivals must shed");
    println!(
        "  at the in-flight cap -> 503 overloaded, Retry-After {}s",
        shed.header("Retry-After").unwrap_or("?"),
    );
    drop(permits);

    // --- Robustness rails: deadlines and the panic circuit breaker. ---
    // (CLI equivalents: --request-deadline-ms, --quarantine-threshold,
    // --max-connections.) An already-expired X-Deadline-Ms budget sheds
    // pre-execution with 504 — no engine time burned.
    let expired = http.infer_with_deadline_ms("alpha", probe.example(2, 2).0.data(), 0)?;
    assert_eq!(expired.status, 504, "expired deadline must shed with 504");
    println!("  X-Deadline-Ms: 0 -> 504 deadline_exceeded (shed before execution)");
    // Install a deliberately faulty model (injected panic on every batch):
    // each failure is contained to a 500, and the breaker quarantines the
    // model at the threshold while its siblings keep serving.
    let registry = server.registry();
    registry.set_quarantine(QuarantineConfig { threshold: 2, ..Default::default() });
    registry.install_with(
        demo_artifact("gamma", 1, 8, 77),
        PathBuf::from("<demo:gamma>"),
        Some(FaultPlan { panic_every: 1, ..Default::default() }),
    );
    let gamma_probe = ClassificationSet::new(16, 8, 13);
    for i in 0..2u64 {
        let r = http.infer("gamma", gamma_probe.example(2, i).0.data())?;
        assert_eq!(r.status, 500, "injected panic must map to a contained 500");
    }
    let r = http.infer("gamma", gamma_probe.example(2, 2).0.data())?;
    assert_eq!(r.status, 503, "two panics must trip the breaker");
    assert!(r.body_text().contains("quarantined"), "{}", r.body_text());
    let ok = http.infer("alpha", probe.example(2, 3).0.data())?;
    assert_eq!(ok.status, 200, "healthy models keep serving through gamma's quarantine");
    println!("  faulty gamma: 500, 500 -> 503 quarantined (K=2); alpha kept serving");

    // --- Fleet lifecycle: drained eviction, cold tombstone, reinstall. ---
    // (CLI equivalents: --max-resident-models, --prepare.) Eviction drains
    // in-flight traffic like a hot swap, then retires the model to a cold
    // tombstone: requests 404, /healthz still lists it (status "cold"),
    // and `install_model` brings it back from the artifact on disk.
    let beta_img = gamma_probe.example(2, 3).0;
    let before = http.infer("beta", beta_img.data())?;
    assert_eq!(before.status, 200);
    let retired = server.evict_model("beta")?;
    let gone = http.infer("beta", beta_img.data())?;
    assert_eq!(gone.status, 404, "an evicted model routes like an unknown one");
    let health = http.get("/healthz")?.body_text();
    assert!(health.contains("\"resident\":\"cold\""), "healthz must list the tombstone: {health}");
    let (name, version) = server.install_model(&dir.join("beta.iaoiq"))?;
    assert_eq!((name.as_str(), version), ("beta", 1));
    let back = http.infer("beta", beta_img.data())?;
    assert_eq!(back.status, 200);
    for (b, a) in back.body_f32()?.iter().zip(before.body_f32()?.iter()) {
        assert_eq!(b.to_bits(), a.to_bits(), "reinstalled beta must serve identical outputs");
    }
    println!(
        "  evicted beta v{retired} (drained, tombstoned cold) -> 404; \
         reinstalled v{version}, outputs bit-identical"
    );

    let report = server.shutdown();
    assert!(report.drained_clean);
    println!(
        "  drained clean (admitted {}, shed {}) — socket front end OK",
        report.admitted, report.shed
    );

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
