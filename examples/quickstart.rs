//! Quickstart: the three-layer composition in one page.
//!
//! 1. Load the AOT-compiled **Pallas integer-matmul kernel** (L1, lowered
//!    to HLO text by `make artifacts`) and run it through PJRT.
//! 2. Run the *same* quantized GEMM on the pure-Rust integer engine (L3)
//!    and verify bit-exact agreement.
//! 3. Post-training-quantize a small float ConvNet and compare the float
//!    and integer-only engines on one image.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use iaoi::data::{ClassificationSet, Rng};
use iaoi::graph::builders::papernet_random;
use iaoi::nn::FusedActivation;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::path::Path;

fn main() -> Result<()> {
    // --- Steps 1 + 2: L1 Pallas kernel vs L3 Rust engine, bit-exact. ---
    let artifacts = Path::new("artifacts");
    if artifacts.join("quickstart.hlo.txt").exists() {
        iaoi::harness::quickstart(artifacts)?;
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT half; continuing)");
    }

    // --- Step 3: quantize a float model and run integer-only inference. ---
    println!("\nPost-training quantization of a small ConvNet (§3 Algorithm 1):");
    let float_model = papernet_random(16, FusedActivation::Relu6, 42);

    // Calibration batches (eq. 13 ranges come from these).
    let mut rng = Rng::seeded(1);
    let calib: Vec<Tensor<f32>> = (0..4)
        .map(|_| {
            let mut d = vec![0f32; 2 * 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[2, 16, 16, 3], d)
        })
        .collect();
    let (folded, int8_model) = quantize_graph(&float_model, &calib, QuantizeOptions::default());
    println!(
        "  model size: float {} B -> int8 {} B ({:.2}x smaller)",
        folded.model_bytes(),
        int8_model.model_bytes(),
        folded.model_bytes() as f64 / int8_model.model_bytes() as f64
    );

    // One real image through both engines.
    let ds = ClassificationSet::new(16, 16, 7);
    let (img, label) = ds.example(0, 0);
    let float_logits = folded.run(&img);
    let int8_logits = int8_model.run(&img);
    let argmax = |t: &Tensor<f32>| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    println!("  true label {label}; float argmax {}, int8 argmax {}", argmax(&float_logits), argmax(&int8_logits));
    println!(
        "  max |float - int8| logit diff: {:.4}",
        float_logits.max_abs_diff(&int8_logits)
    );
    println!("\nquickstart OK");
    Ok(())
}
