//! Detection example (the paper's §4.2.2/4.2.3 workload shape): run the
//! SSD-lite detector on the synthetic detection set through both engines,
//! decode grid predictions into boxes, and report the int8 engine's
//! fidelity to the float detector plus both latencies — a self-contained
//! miniature of `iaoi bench --table 4.4`.
//!
//! Run: `cargo run --release --example detect [images]`

use anyhow::Result;
use iaoi::data::synth::DetectionSet;
use iaoi::graph::builders::ssd_lite;
use iaoi::harness::time_median_ms;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;

fn main() -> Result<()> {
    let images: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let (res, grid, classes) = (32usize, 4usize, 3usize);
    let ds = DetectionSet::new(res, grid, classes, 77);

    // Float detector (BN folded) and its PTQ int8 twin.
    let float_det = ssd_lite(1.0, classes, 9).fold_batch_norms();
    let calib: Vec<Tensor<f32>> = (0..4).map(|i| ds.example(0, i).0).collect();
    let (_, int8_det) = quantize_graph(&float_det, &calib, QuantizeOptions::default());
    println!(
        "SSD-lite: float {} B -> int8 {} B ({:.2}x)",
        float_det.model_bytes(),
        int8_det.model_bytes(),
        float_det.model_bytes() as f64 / int8_det.model_bytes() as f64
    );

    // Detection agreement: int8 boxes vs float boxes, plus recall of the
    // *ground-truth* boxes by both (untrained head: GT recall is luck;
    // agreement is the quantization-relevant number).
    let mut agree = 0usize;
    let mut total_float = 0usize;
    let mut total_int8 = 0usize;
    for i in 0..images {
        let (img, _gt) = ds.example(1, i as u64);
        let fboxes = ds.decode_predictions(&float_det.run(&img), 0.5);
        let qboxes = ds.decode_predictions(&int8_det.run(&img), 0.5);
        total_float += fboxes.len();
        total_int8 += qboxes.len();
        for (fb, _) in &fboxes {
            if qboxes.iter().any(|(qb, _)| qb.class == fb.class && qb.iou(fb) >= 0.5) {
                agree += 1;
            }
        }
    }
    println!(
        "decoded boxes over {images} images: float {total_float}, int8 {total_int8}, matched@IoU0.5 {agree}"
    );
    if total_float > 0 {
        println!("int8 reproduces {:.1}% of float detections", 100.0 * agree as f32 / total_float as f32);
    }

    let (x1, _) = ds.example(1, 0);
    let fms = time_median_ms(10, || {
        let _ = float_det.run(&x1);
    });
    let qms = time_median_ms(10, || {
        let _ = int8_det.run(&x1);
    });
    println!("latency: float {fms:.3} ms/img, int8 {qms:.3} ms/img ({:.2}x)", fms / qms);
    println!("detect example OK");
    Ok(())
}
