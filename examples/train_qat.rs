//! End-to-end driver (the repo's required full-system workload): QAT-train
//! the PaperNet classifier on the synthetic SynthShapes corpus by executing
//! the AOT `train_step` artifact from Rust, log the loss curve, then:
//!
//! * evaluate the float model (AOT `eval_float`),
//! * evaluate the quantization-*simulation* (AOT `eval_qsim`, which embeds
//!   the L1 Pallas fake-quant kernel),
//! * export folded weights + learned ranges (eq. 14, §3.1),
//! * convert to the pure-Rust **integer-only** engine and compare accuracy
//!   and single-image latency against the float engine,
//!
//! proving that training arithmetic and inference arithmetic correspond —
//! the paper's central co-design claim.
//!
//! Run: `make artifacts && cargo run --release --example train_qat [steps]`

use anyhow::Result;
use iaoi::data::ClassificationSet;
use iaoi::harness::{accuracy, papernet_from_params, papernet_int8, time_median_ms};
use iaoi::nn::FusedActivation;
use iaoi::quantize::QuantizeOptions;
use iaoi::train::{Knobs, Trainer};
use std::path::Path;

fn main() -> Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let artifacts = Path::new("artifacts").join("base");
    let mut trainer = Trainer::new(&artifacts, 0)?.with_knobs(Knobs::default());
    let spec = trainer.spec.clone();
    println!(
        "QAT-training PaperNet: res {}, {} classes, batch {}, {} steps (delay {} steps, §3.1)",
        spec.resolution, spec.num_classes, spec.batch, steps, spec.act_quant_delay
    );

    // --- training loop, loss curve logged ---
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let loss = trainer.train_step()?;
        if s % 25 == 0 || s + 1 == steps {
            println!("step {s:>5}  loss {loss:.4}");
        }
    }
    println!(
        "loss curve: first {:.3} -> last {:.3} over {steps} steps ({:.1} steps/s)",
        trainer.losses.first().unwrap(),
        trainer.losses.last().unwrap(),
        steps as f64 / t0.elapsed().as_secs_f64(),
    );

    // --- evaluation through all three arithmetic paths ---
    let acc_float = trainer.eval_float(8)?;
    let acc_qsim = trainer.eval_qsim(8)?;
    println!("\naccuracy (AOT graphs): float {:.2}%  quant-sim {:.2}%", acc_float * 100.0, acc_qsim * 100.0);

    let params = trainer.export_folded()?;
    let ranges = trainer.learned_ranges()?;
    println!("learned activation ranges (EMA, §3.1):");
    for (name, (mn, mx)) in &ranges {
        println!("  {name:<12} [{mn:+.3}, {mx:+.3}]");
    }

    let float_engine = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
    let int8_engine = papernet_int8(
        &params,
        &ranges,
        &spec.export_keys,
        FusedActivation::Relu6,
        QuantizeOptions::default(),
    )?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 0);
    let acc_f_engine = accuracy(&mut |x| float_engine.run(x), &ds, 8, spec.batch);
    let acc_q_engine = accuracy(&mut |x| int8_engine.run(x), &ds, 8, spec.batch);

    let (x1, _) = ds.batch(1, 0, 1);
    let ms_f = time_median_ms(20, || {
        let _ = float_engine.run(&x1);
    });
    let ms_q = time_median_ms(20, || {
        let _ = int8_engine.run(&x1);
    });

    println!("\nRust engines on exported weights:");
    println!("  float32     : top-1 {:.2}%  {ms_f:.3} ms/img  {} B", acc_f_engine * 100.0, float_engine.model_bytes());
    println!("  integer-only: top-1 {:.2}%  {ms_q:.3} ms/img  {} B", acc_q_engine * 100.0, int8_engine.model_bytes());
    println!(
        "  gap {:+.2}%  |  speedup {:.2}x  |  {:.2}x smaller",
        (acc_q_engine - acc_f_engine) * 100.0,
        ms_f / ms_q,
        float_engine.model_bytes() as f64 / int8_engine.model_bytes() as f64
    );

    // Cross-check: the quant-sim (training arithmetic) and the integer
    // engine (inference arithmetic) must agree — fig. 1.1a ≈ fig. 1.1b.
    let gap = (acc_qsim - acc_q_engine).abs();
    println!("\nquant-sim vs integer-engine accuracy gap: {:.2}% (co-design check)", gap * 100.0);
    anyhow::ensure!(gap < 0.1, "training and inference arithmetic diverged");
    println!("train_qat OK");
    Ok(())
}
