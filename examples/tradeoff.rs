//! Latency-vs-accuracy trade-off in miniature (fig. 1.1c's shape): sweep
//! PaperNet width multipliers, train each point float and QAT via the AOT
//! artifacts, and print the two trade-off series with host-measured and
//! Snapdragon-estimated latencies.
//!
//! This is a thinner, example-sized version of `iaoi bench --fig 1.1c`
//! (fewer points, fewer steps) meant to run in about a minute.
//!
//! Run: `make artifacts && cargo run --release --example tradeoff`

use anyhow::Result;
use iaoi::data::ClassificationSet;
use iaoi::harness::{accuracy, papernet_from_params, papernet_int8, time_median_ms};
use iaoi::nn::FusedActivation;
use iaoi::quantize::QuantizeOptions;
use iaoi::sim::{ArmCoreModel, Dtype};
use iaoi::train::{Knobs, Trainer};
use std::path::PathBuf;

fn main() -> Result<()> {
    let steps = 150u64;
    let little = ArmCoreModel::s835_little();
    println!("| variant | type | acc | host ms/img | S835-LITTLE est. ms |");
    println!("|---|---|---|---|---|");
    for variant in ["dm050_r16", "base", "dm200_r16"] {
        let dir = PathBuf::from("artifacts").join(variant);
        for quant in [false, true] {
            let knobs = if quant { Knobs::default() } else { Knobs::float_baseline() };
            let mut tr = Trainer::new(&dir, 2)?.with_knobs(knobs);
            for _ in 0..steps {
                tr.train_step()?;
            }
            let spec = tr.spec.clone();
            let params = tr.export_folded()?;
            let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 2);
            let (x1, _) = ds.batch(1, 0, 1);
            let shape = [1usize, spec.resolution, spec.resolution, 3];
            let fgraph = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
            if quant {
                let ranges = tr.learned_ranges()?;
                let qgraph = papernet_int8(
                    &params,
                    &ranges,
                    &spec.export_keys,
                    FusedActivation::Relu6,
                    QuantizeOptions::default(),
                )?;
                let acc = accuracy(&mut |x| qgraph.run(x), &ds, 4, spec.batch);
                let ms = time_median_ms(10, || {
                    let _ = qgraph.run(&x1);
                });
                let est = little.latency_ms(&fgraph, &shape, Dtype::Int8);
                println!("| {variant} | int8 | {:.1}% | {ms:.3} | {est:.2} |", acc * 100.0);
            } else {
                let acc = accuracy(&mut |x| fgraph.run(x), &ds, 4, spec.batch);
                let ms = time_median_ms(10, || {
                    let _ = fgraph.run(&x1);
                });
                let est = little.latency_ms(&fgraph, &shape, Dtype::F32);
                println!("| {variant} | float | {:.1}% | {ms:.3} | {est:.2} |", acc * 100.0);
            }
        }
    }
    println!("\n(the paper's claim: at matched latency, the int8 series sits above the float series)");
    Ok(())
}
